"""Quickstart: the paper's two algorithms on a small graph, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (exact_pagerank, improved_pagerank, l1_error,
                        normalized, power_iteration, simple_pagerank,
                        topk_overlap, walks_per_node_for)
from repro.graphs import barabasi_albert


def main():
    eps = 0.2
    g = barabasi_albert(512, 3, seed=0)
    print(f"graph: n={g.n} m={g.m} (Barabási–Albert power-law)")

    # classical baseline the paper argues against
    pi_ref, delta, iters = power_iteration(g, eps)
    print(f"power iteration: {iters} iterations to L1 delta {delta:.2e}")

    # Algorithm 1: SIMPLE-PAGERANK (O(log n / eps) rounds)
    K = walks_per_node_for(g.n, eps)
    res = simple_pagerank(g, eps, walks_per_node=K,
                          key=jax.random.PRNGKey(0), traced=True)
    print(f"SIMPLE-PAGERANK: K={K} walks/node, "
          f"{res.logical_rounds} logical rounds, "
          f"{res.report.congest_rounds} CONGEST rounds, "
          f"max bits/edge/round={res.report.max_bits_per_edge_per_round}")
    print(f"  L1 vs baseline: {l1_error(normalized(res.pi), pi_ref):.4f}  "
          f"top-10 overlap: {topk_overlap(res.pi, np.asarray(pi_ref)):.2f}")

    # Algorithm 2: IMPROVED-PAGERANK (O(sqrt(log n)/eps) rounds)
    res2 = improved_pagerank(g, eps, walks_per_node=K,
                             key=jax.random.PRNGKey(1))
    print(f"IMPROVED-PAGERANK: lambda={res2.lam}, "
          f"{res2.stitch_iterations} stitch iters, "
          f"{res2.report.congest_rounds} CONGEST rounds "
          f"({res.report.congest_rounds / res2.report.congest_rounds:.1f}x "
          f"fewer than SIMPLE)")
    print(f"  L1 vs baseline: {l1_error(normalized(res2.pi), pi_ref):.4f}  "
          f"coupons used/created: {res2.coupons_used}/{res2.coupons_created}")


if __name__ == "__main__":
    main()
