"""Serve a small model with continuous batching (batched requests, staggered
admission, per-slot KV caches).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import get_model
from repro.serve import ContinuousBatcher, Request


def main():
    cfg = reduced_config("qwen3-32b")
    model = get_model(cfg)
    params, _ = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 24))).astype(np.int32),
                max_new_tokens=int(rng.integers(4, 16)))
        for i in range(12)
    ]
    batcher = ContinuousBatcher(model, params, cfg, slots=4, max_seq=64)
    t0 = time.time()
    stats = batcher.run(requests)
    dt = time.time() - t0
    print(f"served {stats.completed} requests in {stats.steps} decode steps "
          f"({stats.prefills} prefills), {stats.tokens_out} tokens, "
          f"{dt:.1f}s ({stats.tokens_out/dt:.1f} tok/s on CPU)")
    for r in requests[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.generated[:8]}{'...' if len(r.generated) > 8 else ''}")
    assert stats.completed == len(requests)


if __name__ == "__main__":
    main()
