"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on CPU with the full production code path (sharded train_step,
AdamW/ZeRO, checkpointing, deterministic data pipeline).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # ~110M params: qwen2 family, narrowed (few hundred steps on CPU; on a
    # real slice pass --production-mesh via repro.launch.train instead)
    base = get_config("qwen2-7b")
    cfg = dataclasses.replace(
        base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000, pad_q_heads_to=None)
    n = cfg.param_count()
    print(f"model: {cfg.name}-100m  params={n/1e6:.1f}M")

    with tempfile.TemporaryDirectory() as ckpt:
        params, opt, losses = run_training(
            cfg, steps=args.steps, global_batch=4, seq_len=128,
            lr=1e-3, num_microbatches=2, checkpoint_dir=ckpt,
            checkpoint_every=100, q_chunk=64, log_every=20)
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
