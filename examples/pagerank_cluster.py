"""Distributed PageRank on a device mesh with injected failures.

Simulates a pod: 8 forced host devices, vertex-sharded graph, all_to_all
walk routing, checkpoint-restart supervision with two injected node
failures, and exact-recovery validation.

    python examples/pagerank_cluster.py     (sets its own XLA_FLAGS)
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax
import numpy as np

from repro.launch.pagerank import run


def main():
    print(f"devices: {len(jax.devices())}")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("--- clean run ---")
        pi_clean = run(n=256, eps=0.2, walks_per_node=64,
                       graph_kind="erdos_renyi", checkpoint_dir=None,
                       fail_at=[])
        print("--- run with failures at rounds 6 and 17 ---")
        pi_ft = run(n=256, eps=0.2, walks_per_node=64,
                    graph_kind="erdos_renyi", checkpoint_dir=ckpt_dir,
                    fail_at=[6, 17])
    exact = np.array_equal(np.asarray(pi_clean), np.asarray(pi_ft))
    print(f"recovered run bit-exact with clean run: {exact}")
    assert exact


if __name__ == "__main__":
    main()
