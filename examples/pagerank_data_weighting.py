"""Integration: the paper's PageRank as a data-curation stage.

A synthetic document hyperlink graph is scored with SIMPLE-PAGERANK; the
scores weight the training-data sampler (classic web-corpus curation), and
we verify the realized document distribution follows PageRank importance.

    PYTHONPATH=src python examples/pagerank_data_weighting.py
"""
import jax
import numpy as np

from repro.core import normalized, simple_pagerank
from repro.data import DataConfig, PageRankWeightedSampler
from repro.graphs import doc_link_graph


def main():
    n_docs = 400
    g = doc_link_graph(n_docs, seed=0)
    res = simple_pagerank(g, eps=0.15, walks_per_node=64,
                          key=jax.random.PRNGKey(0))
    scores = np.asarray(normalized(res.pi))
    print(f"scored {n_docs} docs; top-5: {np.argsort(-scores)[:5].tolist()}")

    sampler = PageRankWeightedSampler(
        scores, DataConfig(vocab_size=1024, seq_len=64, global_batch=32))
    batch = sampler.batch_at(0)
    print(f"batch: tokens{batch['tokens'].shape} doc_ids sample "
          f"{batch['doc_ids'][:8].tolist()}")

    freq = sampler.empirical_doc_freq(steps=200)
    corr = np.corrcoef(freq, scores)[0, 1]
    top_score = set(np.argsort(-scores)[:20].tolist())
    top_freq = set(np.argsort(-freq)[:20].tolist())
    print(f"empirical-vs-PageRank corr: {corr:.3f}  "
          f"top-20 overlap: {len(top_score & top_freq)}/20")
    assert corr > 0.9


if __name__ == "__main__":
    main()
