"""Run the paper-validation benchmarks and write the §Paper-validation
markdown consumed by make_experiments.py.

    PYTHONPATH=src python scripts/make_paper_validation.py
"""
from __future__ import annotations

import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)


def main():
    from benchmarks import (bench_accuracy, bench_congestion, bench_directed,
                            bench_rounds)

    lines = ["## §Paper-validation", "",
             "The faithful reproduction, validated against the paper's own "
             "claims before any optimization (all numbers measured by the "
             "CONGEST accounting layer over the count-message engine / "
             "stitched algorithm)."]

    rows = bench_rounds.run()
    lines += ["", "### Theorem 1 & 2 — round complexity", "",
              "| n | eps | SIMPLE congest rounds | IMPROVED congest rounds | "
              "speedup |", "|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['n']} | {r['eps']} | {r['simple_congest']} | "
                     f"{r['improved_congest']} | {r['ratio']:.2f}× |")
    import numpy as np
    sub = [r for r in rows if r["n"] == max(x["n"] for x in rows)]
    inv_eps = np.array([1 / r["eps"] for r in sub])
    simple = np.array([r["simple_congest"] for r in sub], float)
    improved = np.array([r["improved_congest"] for r in sub], float)
    s_slope = np.polyfit(inv_eps, simple, 1)
    i_slope = np.polyfit(inv_eps, improved, 1)
    lines += ["",
              f"SIMPLE rounds ≈ {s_slope[0]:.1f}·(1/ε) + {s_slope[1]:.1f} — "
              "**linear in 1/ε** (Theorem 1: O(log n/ε)); IMPROVED rounds ≈ "
              f"{i_slope[0]:.1f}·(1/ε) + {i_slope[1]:.1f} with a "
              f"{s_slope[0]/max(i_slope[0],1e-9):.1f}× smaller slope "
              "(Theorem 2: the λ=√log n stitching divides the ε-dependence "
              "of the walk phase). At fixed ε the n-dependence of both is "
              "logarithmic (rows above grow ~log n across 8× in n)."]

    rows = bench_accuracy.run()
    lines += ["", "### Monte-Carlo accuracy vs K (Avrachenkov claim)", "",
              "| K walks/node | SIMPLE L1 | IMPROVED L1 | directed L1 | "
              "top-10 overlap |", "|---|---|---|---|---|"]
    for r in rows:
        tag = " (paper's K=c·log n)" if r.get("paper_K") else ""
        lines.append(f"| {r['K']}{tag} | {r['simple_l1']:.4f} | "
                     f"{r['improved_l1']:.4f} | {r['directed_l1']:.4f} | "
                     f"{r['top10']:.2f} |")
    lines += ["", "L1 error shrinks ~1/√K; at the paper's K = c·log n the "
              "estimate is already ranking-accurate (top-10 overlap ≈ 1) — "
              "matching \"one iteration is sufficient\"."]

    rows = bench_congestion.run()
    lines += ["", "### Lemma 1 / Lemma 3 — congestion", "",
              "| K | total walks | max bits/edge/round | B (CONGEST) | "
              "CONGEST rounds |", "|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['K']} | {r['walks']} | {r['max_bits']} | "
                     f"{r['bandwidth_B']} | {r['congest']} |")
    lines += ["", "100× more parallel walks cost ~log-factor more bits per "
              "edge (counts, never identities): the Lemma-1 mechanism. "
              "Payloads stay under B = Θ(log²n), so logical rounds == "
              "CONGEST rounds."]

    rows = bench_directed.run()
    lines += ["", "### Theorem 3 — directed graphs in LOCAL", "",
              "| n | λ | logical rounds (P1+P2+P3) | coupons created | L1 |",
              "|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['n']} | {r['lam']} | {r['logical']} | "
                     f"{r['coupons']} | {r['l1']:.4f} |")
    lines += ["", "Directed variant: λ=√(log n/ε), polynomial per-node "
              "coupon pools (LOCAL model), sub-logarithmic round counts; "
              "accuracy matches the undirected case."]

    os.makedirs(os.path.join(ROOT, "results"), exist_ok=True)
    with open(os.path.join(ROOT, "results", "paper_validation.md"), "w") as f:
        f.write("\n".join(lines))
    print("results/paper_validation.md written")


if __name__ == "__main__":
    main()
