#!/usr/bin/env python
"""CONGEST wire-budget + lint audit over every distributed engine.

Traces each engine's own jitted stage programs to jaxprs, checks every
collective against its declared W-free lane budget, runs the RNG / dtype /
elastic-schema lints, executes the engines on fixture graphs to cross-check
the static widths against runtime telemetry, prints the wire-budget table,
and writes machine-readable AUDIT.json. `--strict` exits non-zero on any
violation — that is the CI gate.

Usage:
    python scripts/audit_engines.py --strict --out AUDIT.json
    python scripts/audit_engines.py --devices 8 --engines walks counts
"""
import argparse
import json
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any violation (CI gate)")
    ap.add_argument("--out", default="AUDIT.json",
                    help="path for the machine-readable report")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (shards)")
    ap.add_argument("--engines", nargs="*", default=None,
                    help="subset of engines (default: all five)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="audit the pallas variants of the hot paths")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="static checks only (skip the fixture runs)")
    ap.add_argument("--eps", type=float, default=0.2)
    ap.add_argument("--walks-per-node", type=int, default=2)
    args = ap.parse_args()

    # must happen before jax import
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    from repro.analysis.congest import audit_all_engines, format_wire_table

    report = audit_all_engines(
        use_pallas=args.use_pallas,
        run_telemetry=not args.no_telemetry,
        eps=args.eps, walks_per_node=args.walks_per_node,
        engines=tuple(args.engines) if args.engines else None)
    print(format_wire_table(report))
    for e in report["engines"].values():
        for v in e["violations"]:
            print(f"VIOLATION [{v['engine']}] {v['kind']} at {v['where']}: "
                  f"{v['message']}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")
    if args.strict and not report["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
