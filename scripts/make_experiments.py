"""Generate EXPERIMENTS.md from dry-run JSONs + benchmark runs.

    PYTHONPATH=src python scripts/make_experiments.py
"""
from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results", "dryrun")
PERF_LOG = os.path.join(ROOT, "results", "perf_iterations.json")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["deepseek-v2-236b", "dbrx-132b", "qwen2-7b", "nemotron-4-340b",
              "h2o-danube-3-4b", "qwen3-32b", "mamba2-1.3b",
              "recurrentgemma-9b", "internvl2-1b", "whisper-tiny"]


def load_cells():
    cells = {}
    for path in glob.glob(os.path.join(RESULTS, "*.json")):
        with open(path) as f:
            c = json.load(f)
        cells[(c["arch"], c["shape"], c["mesh"])] = c
    return cells


def _fmt_t(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f}µs"
    if s < 1:
        return f"{s*1e3:.1f}ms"
    return f"{s:.2f}s"


def _fmt_b(b):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.1f}{unit}"
    return f"{b:.0f}B"


def _move_note(c):
    r = c["roofline"]
    bn = r["bottleneck"]
    kind = c["shape"].split("_")[0]
    if bn == "collective":
        top = max(r["coll_breakdown"], key=r["coll_breakdown"].get) \
            if r["coll_breakdown"] else "all-reduce"
        return (f"dominant {top} volume — reshard to keep activations on "
                "fewer axes / compress the DP reduction (int8 EF)")
    if bn == "memory":
        if kind in ("decode", "long"):
            return ("cache/weight streaming bound — raise per-chip batch or "
                    "quantize KV; absorbed-MLA already minimizes cache reads")
        if kind == "prefill":
            return "weight+activation streaming — larger q-chunks raise reuse"
        return ("bytes-bound under full remat — save dot outputs "
                "(checkpoint_dots) to trade HBM for recompute")
    return ("compute-bound — reduce remat recompute (policy) and overlap "
            "collectives behind the MXU")


def dryrun_section(cells):
    out = ["## §Dry-run", "",
           "Every (arch × shape × mesh) lowered with ShapeDtypeStruct inputs "
           "and compiled on forced-host-device production meshes "
           "(single-pod 16×16 = 256 chips, multi-pod 2×16×16 = 512 chips). "
           "`.lower().compile()` succeeds for **every applicable cell**; "
           "`long_500k` is inapplicable to the seven pure full-attention "
           "archs (DESIGN.md §Arch-applicability).", ""]
    for mesh in ("pod16x16", "pod2x16x16"):
        out += [f"### Mesh {mesh}", "",
                "| arch | shape | status | params | peak mem/dev | "
                "args/dev | HLO GFLOP/chip | collectives (corrected) | "
                "compile |",
                "|---|---|---|---|---|---|---|---|---|"]
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                c = cells.get((arch, shape, mesh))
                if c is None:
                    continue
                if c["status"] == "skipped":
                    out.append(f"| {arch} | {shape} | SKIP (full attn) | | | | | | |")
                    continue
                if c["status"] != "ok":
                    out.append(f"| {arch} | {shape} | **ERROR** "
                               f"{c['reason'][:60]} | | | | | | |")
                    continue
                r = c["roofline"]
                mem = c["memory"]
                peak = mem.get("peak_memory_in_bytes", 0) or (
                    mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0))
                coll = ", ".join(f"{k}:{_fmt_b(v)}" for k, v in
                                 sorted(r["coll_breakdown"].items(),
                                        key=lambda kv: -kv[1])[:3]) or "—"
                out.append(
                    f"| {arch} | {shape} | ok | "
                    f"{c['params_total']/1e9:.1f}B | {_fmt_b(peak)} | "
                    f"{_fmt_b(mem.get('argument_size_in_bytes', 0))} | "
                    f"{r['hlo_flops']/1e9:.0f} | {coll} | "
                    f"{c['t_compile_s']}s |")
        out.append("")
    return out


def roofline_section(cells):
    out = ["## §Roofline", "",
           "Three-term model per cell (single-pod mesh; TPU v5e constants: "
           "197 TF/s bf16, 819 GB/s HBM, 4×50 GB/s ICI links/chip). "
           "FLOPs/bytes/collective volumes are **calibrated**: XLA's "
           "`cost_analysis()` counts `while`-loop bodies once, so each cell "
           "is re-measured at two unrolled layer counts (full widths) and "
           "the exact linear model `cost = fixed + per_layer·L` is solved "
           "(`calibration` block in each JSON). `useful` = MODEL_FLOPS "
           "(6·N_active·D train / 2·N_active·D serve) over total corrected "
           "HLO FLOPs — attention's quadratic term and remat recompute "
           "legitimately push it below 1. The memory term uses HLO "
           "bytes-accessed, an unfused upper bound on HBM traffic (noted "
           "per cell where it overstates).", "",
           "| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | useful | roofline-MFU | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape, "pod16x16"))
            if c is None or c["status"] != "ok":
                continue
            r = c["roofline"]
            out.append(
                f"| {arch} | {shape} | {_fmt_t(r['t_compute'])} | "
                f"{_fmt_t(r['t_memory'])} | {_fmt_t(r['t_collective'])} | "
                f"{r['bottleneck']} | {r['useful_flops_fraction']:.2f} | "
                f"{r['mfu']:.3f} | {_move_note(c)} |")
    out.append("")
    return out


def perf_section():
    out = ["## §Perf", "",
           "Hillclimb protocol: every (arch × shape) pair baselined "
           "(§Roofline table above reflects the FINAL state); the three "
           "most interesting targets iterated hypothesis → change → "
           "measure → verdict. Targets: (1) the paper's own technique "
           "(distributed PageRank engine — most representative), (2) "
           "dbrx-132b × train_4k (worst useful-FLOPs fraction, 0.043), "
           "(3) qwen2-7b × train_4k (indivisible-heads pathology; also the "
           "most collective-distorted once FSDP landed). Paper-faithful "
           "baselines and beyond-paper optimized versions are recorded "
           "separately in each table.", "",
           "Headline results:", "",
           "| target | paper-faithful baseline | optimized | gain |",
           "|---|---|---|---|",
           "| PageRank engine (8 shards, K=400) | walk-routing: 841KB "
           "all_to_all to termination | count-aggregated packed lanes: "
           "62KB, overflow-free static bounds | **13.6× less collective "
           "volume; payload now ~flat in walk count** |",
           "| PageRank straggler bound (BA graph) | contiguous partition: "
           "max-shard degree 805 (imbalance 2.70) | degree-balanced "
           "relabeling: 327 (1.10) | **2.46× lower super-step critical "
           "path** |",
           "| dbrx-132b train_4k | 106.7s roofline step, 20.5GB/dev (over "
           "HBM), MFU 0.043 | shard_map MoE + FSDP: 37.6s, 4.2GB/dev, MFU "
           "0.121 | **2.8× step; fits HBM; useful FLOPs 0.04→0.59** |",
           "| qwen2-7b train_4k | replicated attention (28 heads ∤ 16): "
           "52.3s, MFU 0.018 | exact zero-padded heads →32: 10.6s, MFU "
           "0.089 | **4.9× step; useful FLOPs 0.18→0.72** |", ""]
    if os.path.exists(PERF_LOG):
        with open(PERF_LOG) as f:
            log = json.load(f)
        for target in log:
            out += [f"### {target['name']}", "", target.get("summary", ""),
                    ""]
            out += ["| iter | hypothesis | change | before | after | "
                    "verdict |", "|---|---|---|---|---|---|"]
            for i, it in enumerate(target["iterations"]):
                out.append(f"| {i+1} | {it['hypothesis']} | {it['change']} | "
                           f"{it['before']} | {it['after']} | "
                           f"{it['verdict']} |")
            out.append("")
    else:
        out.append("(perf iterations pending — results/perf_iterations.json)")
    return out


def main():
    cells = load_cells()
    ok = sum(1 for c in cells.values() if c["status"] == "ok")
    err = sum(1 for c in cells.values() if c["status"] == "error")
    skip = sum(1 for c in cells.values() if c["status"] == "skipped")

    lines = [
        "# EXPERIMENTS — Fast Distributed PageRank (Das Sarma et al. 2012)",
        "",
        f"Dry-run cells: {len(cells)} total — {ok} compiled ok, "
        f"{skip} skipped (long_500k × full-attention), {err} errors.",
        "",
        "Hardware target: TPU v5e pods (256 chips/pod; 512 across 2 pods). "
        "This container is CPU-only: dry-run compiles use "
        "`--xla_force_host_platform_device_count=512`; Pallas kernels "
        "validate in interpret mode; CONGEST claims validated by the "
        "accounting layer (DESIGN.md §2).",
        "",
    ]
    if os.path.exists(os.path.join(ROOT, "results", "paper_validation.md")):
        with open(os.path.join(ROOT, "results", "paper_validation.md")) as f:
            lines += [f.read(), ""]
    lines += dryrun_section(cells)
    lines += roofline_section(cells)
    lines += perf_section()
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(lines))
    print(f"EXPERIMENTS.md written ({ok} ok / {skip} skip / {err} err)")


if __name__ == "__main__":
    main()
