"""Graph substrate: CSR validity, generator invariants."""
import numpy as np
import pytest

from repro.core.graph import CSRGraph, from_edges, padded_adjacency
from repro.graphs import (barabasi_albert, directed_web, erdos_renyi, grid2d,
                          random_regular, ring)


def _check_csr(g: CSRGraph):
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col_idx)
    deg = np.asarray(g.out_deg)
    assert rp.shape == (g.n + 1,)
    assert rp[0] == 0 and rp[-1] == g.m
    assert (np.diff(rp) == deg).all()
    assert col.shape == (g.m,)
    if g.m:
        assert col.min() >= 0 and col.max() < g.n


@pytest.mark.parametrize("maker", [
    lambda: ring(33), lambda: grid2d(5, 7),
    lambda: erdos_renyi(50, 4.0, seed=1),
    lambda: barabasi_albert(50, 3, seed=1),
    lambda: random_regular(40, 4, seed=1),
    lambda: directed_web(60, 5.0, seed=1),
])
def test_generators_valid_csr(maker):
    g = maker()
    _check_csr(g)


def test_undirected_symmetry():
    g = erdos_renyi(40, 4.0, seed=2)
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col_idx)
    edges = set()
    for v in range(g.n):
        for u in col[rp[v]:rp[v + 1]]:
            edges.add((v, int(u)))
    assert all((u, v) in edges for (v, u) in edges)


def test_directed_no_dangling():
    g = directed_web(80, 5.0, seed=3)
    assert (np.asarray(g.out_deg) > 0).all()


def test_padded_adjacency_roundtrip():
    g = erdos_renyi(30, 4.0, seed=4)
    nbr, valid = padded_adjacency(g)
    rp = np.asarray(g.row_ptr)
    col = np.asarray(g.col_idx)
    for v in range(g.n):
        d = rp[v + 1] - rp[v]
        assert (np.asarray(nbr)[v, :d] == col[rp[v]:rp[v + 1]]).all()
        assert np.asarray(valid)[v, :d].all()
        assert not np.asarray(valid)[v, d:].any()


def test_from_edges_dedup():
    g = from_edges(np.array([0, 0, 1]), np.array([1, 1, 2]), 3)
    assert g.m == 2
