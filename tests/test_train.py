"""Optimizer, ZeRO state, int8 moments, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (AdamWConfig, apply_updates, compressed_psum,
                         compression_error, init_state)
from repro.train.optimizer import (dequantize_blockwise, quantize_blockwise,
                                   state_axes)


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return dict(w=jax.random.normal(k1, (32, 16)).astype(jnp.bfloat16),
                b=jax.random.normal(k2, (16,)).astype(jnp.bfloat16))


def test_adamw_reduces_quadratic(key):
    params = _toy_params(key)
    target = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p, jnp.float32), params)
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0)
    state = init_state(params, cfg)

    def loss(p):
        return sum(jnp.sum((x.astype(jnp.float32) - t) ** 2)
                   for x, t in zip(jax.tree_util.tree_leaves(p),
                                   jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_int8_adam_tracks_fp32(key):
    """int8 moments converge to the same optimum; iterate noise bounded."""
    params = _toy_params(key)
    cfg32 = AdamWConfig(lr=2e-2, weight_decay=0.0)
    cfg8 = AdamWConfig(lr=2e-2, weight_decay=0.0, int8_moments=True)
    s32, s8 = init_state(params, cfg32), init_state(params, cfg8)
    p32 = p8 = params

    def loss(p):
        return sum(jnp.sum((x.astype(jnp.float32) - 1.0) ** 2)
                   for x in jax.tree_util.tree_leaves(p))

    l0 = float(loss(params))
    for _ in range(80):
        p32, s32, _ = apply_updates(p32, jax.grad(loss)(p32), s32, cfg32)
        p8, s8, _ = apply_updates(p8, jax.grad(loss)(p8), s8, cfg8)
    # both optimize the objective; int8 lands near the same optimum
    assert float(loss(p32)) < 0.15 * l0
    assert float(loss(p8)) < 1.1 * float(loss(p32))
    a = jnp.concatenate([x.astype(jnp.float32).reshape(-1)
                         for x in jax.tree_util.tree_leaves(p32)])
    b = jnp.concatenate([x.astype(jnp.float32).reshape(-1)
                         for x in jax.tree_util.tree_leaves(p8)])
    cos = float(jnp.dot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    assert cos > 0.98, cos


def test_quantize_roundtrip_error_bound(key):
    x = jax.random.normal(key, (1024,)) * 3.0
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    # absmax int8: error <= scale/2 per element
    per_block_bound = (jnp.abs(x.reshape(-1, 128)).max(axis=1) / 127.0) / 2.0
    err = jnp.abs((x - back).reshape(-1, 128)).max(axis=1)
    assert (err <= per_block_bound + 1e-6).all()


def test_grad_clip():
    params = dict(w=jnp.zeros((4,), jnp.bfloat16))
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = init_state(params, cfg)
    huge = dict(w=jnp.full((4,), 1e6, jnp.float32))
    _, _, m = apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported unclipped


def test_state_axes_structure(key):
    params = _toy_params(key)
    axes = dict(w=("embed", "ffn"), b=("ffn",))
    for int8 in (False, True):
        st = init_state(params, AdamWConfig(int8_moments=int8))
        ax = state_axes(axes, int8)
        assert (jax.tree_util.tree_structure(st)
                == jax.tree_util.tree_structure(
                    ax, is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x)))


def test_compression_error_feedback_converges(key):
    """With error feedback, the time-average of compressed sums is unbiased:
    accumulated residual stays bounded while the signal accumulates."""
    from repro.train.compression import _dequant, _quant
    x = jax.random.normal(key, (512,))
    residual = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for i in range(50):
        # single-host view of compressed_psum: quantize-with-feedback
        corrected = x + residual
        q, s = _quant(corrected)
        local = _dequant(q, s, x.shape[0])
        residual = corrected - local
        total = total + local
    avg = total / 50
    rel = float(jnp.linalg.norm(avg - x) / jnp.linalg.norm(x))
    assert rel < 0.01, rel
    assert compression_error(x) < 0.05
