"""Engine equivalence + CONGEST accounting (Lemma 1 / Theorem 1)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine_counts, engine_walks
from repro.core.accounting import default_bandwidth
from repro.core.graph import padded_adjacency
from repro.core.simple_pagerank import simple_pagerank
from repro.graphs import erdos_renyi, ring

EPS = 0.25


def test_multinomial_split_exact():
    """Conditional-binomial chain conserves mass and never leaks."""
    g = erdos_renyi(64, 6.0, seed=0)
    nbr, _ = padded_adjacency(g)
    surv = jax.random.randint(jax.random.PRNGKey(1), (g.n,), 0, 50)
    surv = jnp.where(g.out_deg > 0, surv, 0)
    T, rem = engine_counts._multinomial_split(
        jax.random.PRNGKey(2), surv, g.out_deg, int(nbr.shape[1]))
    assert int(rem.sum()) == 0
    np.testing.assert_array_equal(np.asarray(T.sum(axis=1)), np.asarray(surv))
    # nothing lands on padded slots
    valid = np.zeros_like(np.asarray(T), dtype=bool)
    deg = np.asarray(g.out_deg)
    for v in range(g.n):
        valid[v, :deg[v]] = True
    assert (np.asarray(T)[~valid] == 0).all()


def test_engines_agree_in_distribution(small_graphs):
    """Count engine (faithful Alg 1) and walk engine estimate the same pi."""
    g = small_graphs["er"]
    K = 120
    r_counts = simple_pagerank(g, EPS, walks_per_node=K,
                               key=jax.random.PRNGKey(3), engine="counts")
    r_walks = simple_pagerank(g, EPS, walks_per_node=K,
                              key=jax.random.PRNGKey(4), engine="walks")
    a = np.asarray(r_counts.pi) / np.asarray(r_counts.pi).sum()
    b = np.asarray(r_walks.pi) / np.asarray(r_walks.pi).sum()
    assert np.abs(a - b).sum() < 0.15  # two MC estimates of the same vector


def test_rounds_scale_with_inverse_eps():
    """Theorem 1: O(log n / eps) — halving eps ~doubles rounds."""
    g = ring(64)
    r1 = simple_pagerank(g, 0.4, walks_per_node=100, key=jax.random.PRNGKey(5))
    r2 = simple_pagerank(g, 0.1, walks_per_node=100, key=jax.random.PRNGKey(5))
    assert r2.logical_rounds > 2 * r1.logical_rounds


def test_congestion_stays_polylog(small_graphs):
    """Lemma 1: per-edge bits stay ~log(walks), even with many walks."""
    g = small_graphs["er"]
    for K in (10, 100, 1000):
        res = simple_pagerank(g, EPS, walks_per_node=K,
                              key=jax.random.PRNGKey(7), traced=True)
        bits = res.report.max_bits_per_edge_per_round
        # count messages encode values <= total walks: O(log(nK)) bits
        assert bits <= math.ceil(math.log2(g.n * K + 1)) + 8
    # 100x more walks costs only ~log-factor more bits (counts, not IDs)
    assert bits <= 3 * default_bandwidth(g.n)


def test_walk_engine_traced_matches_jit(small_graphs):
    g = small_graphs["ring"]
    key = jax.random.PRNGKey(9)
    s1 = engine_walks.run(g, EPS, 50, key)
    s2, traces = engine_walks.run_traced(g, EPS, 50, key)
    np.testing.assert_array_equal(np.asarray(s1.zeta), np.asarray(s2.zeta))
    assert int(s1.round) == len(traces)


def test_zeta_conservation(small_graphs):
    """sum(zeta) == starts + total moves (every arrival counted once)."""
    g = small_graphs["grid"]
    K = 60
    state, traces = engine_walks.run_traced(g, EPS, K, jax.random.PRNGKey(11))
    total_moves = sum(t.total_count for t in traces)
    assert int(state.zeta.sum()) == g.n * K + total_moves
