"""Checkpoint roundtrip, async writes, elastic relayout."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, relayout_pagerank_state, restore_into


def _tree(key):
    k1, k2 = jax.random.split(key)
    return dict(a=jax.random.normal(k1, (8, 4)),
                nested=dict(b=jax.random.normal(k2, (3,)).astype(jnp.bfloat16),
                            step=jnp.int32(7)))


def test_roundtrip(tmp_path, key):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(key)
    ck.save(5, tree, metadata=dict(note="x"))
    flat, manifest = ck.restore()
    assert manifest["step"] == 5
    restored = restore_into(tree, flat)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    tree = _tree(key)
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=False)
    ck.wait()
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_restore_specific_step(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep_last=0)
    t1 = _tree(key)
    t2 = jax.tree_util.tree_map(lambda x: x + 1 if x.dtype != jnp.int32 else x,
                                t1)
    ck.save(1, t1)
    ck.save(2, t2)
    flat1, _ = ck.restore(step=1)
    r1 = restore_into(t1, flat1)
    np.testing.assert_array_equal(np.asarray(r1["a"]), np.asarray(t1["a"]))


def test_elastic_relayout_preserves_walks():
    n = 64
    pos = np.full((4, 100), -1, np.int32)
    rng = np.random.default_rng(0)
    for p in range(4):
        k = rng.integers(10, 60)
        pos[p, :k] = rng.integers(0, n, size=k)
    zeta = rng.integers(0, 50, size=(4, 16)).astype(np.int32)
    key = np.asarray(jax.random.split(jax.random.PRNGKey(0), 4))
    host = dict(pos=pos, zeta=zeta, key=key, round=9, dropped=0, waited=0)
    for new_shards in (2, 8):
        out = relayout_pagerank_state(host, n, new_shards)
        assert out["pos"].shape[0] == new_shards
        assert (out["pos"] >= 0).sum() == (pos >= 0).sum()
        assert out["zeta"].sum() == zeta.sum()
        # ownership: every live walk sits on its owner shard
        n_loc = out["zeta"].shape[1]
        for p in range(new_shards):
            live = out["pos"][p][out["pos"][p] >= 0]
            assert ((live // n_loc) == p).all()
