"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import engine_counts, routing
from repro.core.graph import from_edges, padded_adjacency
from repro.kernels.histogram import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.models.moe import _rank_within
from repro.train.optimizer import dequantize_blockwise, quantize_blockwise

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(st.lists(st.integers(min_value=-1, max_value=49), min_size=1,
                max_size=400),
       st.integers(min_value=1, max_value=50))
def test_histogram_matches_ref(ids, n):
    ids = jnp.asarray(ids, jnp.int32)
    np.testing.assert_array_equal(np.asarray(histogram(ids, n)),
                                  np.asarray(histogram_ref(ids, n)))


@given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                max_size=300))
def test_rank_within_is_a_ranking(ids):
    ids_j = jnp.asarray(ids, jnp.int32)
    rank = np.asarray(_rank_within(ids_j))
    for v in set(ids):
        ranks_v = sorted(rank[np.asarray(ids) == v].tolist())
        assert ranks_v == list(range(len(ranks_v)))  # 0..k-1, no dup/gap


@given(st.integers(min_value=1, max_value=2**20))
def test_quantize_roundtrip_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    bound = np.asarray(jnp.abs(x.reshape(-1, 128)).max(axis=1)) / 127.0
    err = np.asarray(jnp.abs((x - back).reshape(-1, 128)).max(axis=1))
    assert (err <= bound * 0.51 + 1e-6).all()


@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=0, max_value=100),
       st.integers(min_value=0, max_value=2**16))
def test_multinomial_split_conserves(deg, count, seed):
    """Binomial-chain multinomial: total out == total in, any degree."""
    degs = jnp.asarray([deg, 1, 3], jnp.int32)
    counts = jnp.asarray([count, 5, 0], jnp.int32)
    T, rem = engine_counts._multinomial_split(
        jax.random.PRNGKey(seed), counts, degs, int(degs.max()))
    assert int(rem.sum()) == 0
    np.testing.assert_array_equal(np.asarray(T.sum(axis=1)),
                                  np.asarray(counts))


@given(st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                min_size=1, max_size=100))
def test_csr_total_degree(edges):
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = from_edges(src, dst, 20, undirected=False, dedup=True)
    assert int(np.asarray(g.out_deg).sum()) == g.m
    nbr, valid = padded_adjacency(g)
    assert int(np.asarray(valid).sum()) == g.m


# ---------------------------------------------------------------------------
# CONGEST routing-lane primitives (core/routing.py): every shard_map engine
# moves data through rank_within -> lane_slots -> pack_lanes -> all_to_all,
# so these invariants gate all four distributed engines at once.
# ---------------------------------------------------------------------------

def _check_rank_within(keys):
    rank, _ = routing.rank_within(jnp.asarray(keys, jnp.int32))
    rank, keys = np.asarray(rank), np.asarray(keys)
    for v in set(keys.tolist()):
        ranks_v = rank[keys == v]
        # a permutation of 0..k-1 per equal-key group (no dup, no gap) ...
        assert sorted(ranks_v.tolist()) == list(range(len(ranks_v)))
        # ... assigned stably: rank order == original index order
        assert (np.diff(ranks_v) > 0).all() if len(ranks_v) > 1 else True


@given(st.lists(st.integers(min_value=0, max_value=11), min_size=1,
                max_size=300))
def test_rank_within_stable_ranking(keys):
    _check_rank_within(keys)


def _check_lane_slots(targets, valids, shards, lane_cap):
    t = np.asarray(targets)
    v = np.asarray(valids)
    sendable, flat = routing.lane_slots(
        jnp.asarray(t, jnp.int32), jnp.asarray(v), shards, lane_cap)
    sendable, flat = np.asarray(sendable), np.asarray(flat)
    assert not (sendable & ~v).any()          # only valid items get slots
    for q in range(shards):
        grp = v & (t == q)
        sent = sendable & grp
        # exactly min(|group|, cap) go this round — the rest *wait*,
        # nothing is silently dropped
        assert sent.sum() == min(grp.sum(), lane_cap), q
        slots = flat[sent]
        assert ((slots >= q * lane_cap) & (slots < (q + 1) * lane_cap)).all()
    assert len(set(flat[sendable].tolist())) == int(sendable.sum())
    assert (flat[~sendable] == shards * lane_cap).all()  # sentinel slot


@given(st.integers(min_value=1, max_value=6).flatmap(lambda s: st.tuples(
           st.just(s),
           st.lists(st.tuples(st.integers(0, s - 1), st.booleans()),
                    min_size=1, max_size=120),
           st.integers(min_value=1, max_value=8))))
def test_lane_slots_no_silent_drops(case):
    shards, items, lane_cap = case
    _check_lane_slots([t for t, _ in items], [v for _, v in items],
                      shards, lane_cap)


def _check_pack_exchange_roundtrip(per_shard_targets, lane_cap):
    """Pack every shard's outbox and emulate the tiled all_to_all (shard
    q's block p arrives at shard p as block q): the delivered + waiting
    multisets must equal the sent multiset, each item must land at its
    target shard, and each (src, dst) lane must preserve source order."""
    shards = len(per_shard_targets)
    lanes, waiting = [], []
    sent_to = {q: [] for q in range(shards)}
    for p, targets in enumerate(per_shard_targets):
        t = np.asarray(targets, np.int32)
        values = (p * 1000 + np.arange(len(t))).astype(np.int32)  # traceable
        sendable, flat = routing.lane_slots(
            jnp.asarray(t), jnp.ones(len(t), bool), shards, lane_cap)
        lane = routing.pack_lanes(flat, jnp.asarray(values),
                                  sendable, shards, lane_cap)
        lanes.append(np.asarray(lane).reshape(shards, lane_cap))
        sendable = np.asarray(sendable)
        waiting.extend(values[~sendable].tolist())
        for q in range(shards):
            sent_to[q].extend(values[sendable & (t == q)].tolist())
    delivered = []
    for p in range(shards):
        recv = np.stack([lanes[q][p] for q in range(shards)])  # [src, cap]
        for q in range(shards):
            lane = recv[q][recv[q] >= 0]
            # occupied slots form a prefix in source order (stable ranks)
            assert (recv[q][:len(lane)] >= 0).all()
            assert (np.diff(lane) > 0).all() if len(lane) > 1 else True
        got = recv[recv >= 0].tolist()
        assert sorted(got) == sorted(sent_to[p]), p   # right shard, exactly
        delivered.extend(got)
    total = sum(len(t) for t in per_shard_targets)
    assert len(delivered) + len(waiting) == total     # conservation
    all_values = [p * 1000 + i for p, t in enumerate(per_shard_targets)
                  for i in range(len(t))]
    assert sorted(delivered + waiting) == sorted(all_values)


@given(st.integers(min_value=1, max_value=5).flatmap(lambda s: st.tuples(
           st.lists(st.lists(st.integers(0, s - 1), min_size=1, max_size=40),
                    min_size=s, max_size=s),
           st.integers(min_value=1, max_value=6))))
def test_pack_exchange_roundtrip_conserves(case):
    per_shard_targets, lane_cap = case
    _check_pack_exchange_roundtrip(per_shard_targets, lane_cap)


def _check_merge_walks(kept, recv):
    cap = len(kept)  # engine contract: the buffer IS the kept array
    kept_j = jnp.asarray(kept, jnp.int32)
    recv_j = jnp.asarray(recv, jnp.int32)
    tag = lambda pos: jnp.where(pos >= 0, pos * 7 + 1, 0)  # paired payload
    pos, fields, dropped = routing.merge_walks(
        kept_j, {"x": tag(kept_j)}, recv_j, {"x": tag(recv_j)}, cap)
    pos, x = np.asarray(pos), np.asarray(fields["x"])
    n_kept = int((np.asarray(kept) >= 0).sum())
    n_recv = int((np.asarray(recv) >= 0).sum())
    assert pos.shape == (cap,)
    assert int((pos >= 0).sum()) == min(n_kept + n_recv, cap)
    assert int(dropped) == max(0, n_kept + n_recv - cap)
    # payload columns travel with their walk through the compaction
    assert (x[pos >= 0] == pos[pos >= 0] * 7 + 1).all()
    surviving = pos[pos >= 0].tolist()
    kept_valid = [p for p in kept if p >= 0]
    pool = kept_valid + [p for p in recv if p >= 0]
    if int(dropped) == 0:
        assert sorted(surviving) == sorted(pool)
    else:
        # resident walks are never the ones dropped (they sort first)
        assert sorted(surviving[:n_kept]) == sorted(kept_valid)
        remainder = list(surviving)
        for p in pool:  # surviving ⊆ pool as multisets
            if p in remainder:
                remainder.remove(p)
        assert not remainder


@given(st.lists(st.integers(min_value=-1, max_value=99), min_size=1,
                max_size=60),
       st.lists(st.integers(min_value=-1, max_value=99), min_size=1,
                max_size=60))
def test_merge_walks_conserves_and_drops_exactly(kept, recv):
    _check_merge_walks(kept, recv)


# ---------------------------------------------------------------------------
# degree-bucketed aggregate sampler (core/aggregate_sampler): the static
# layout machinery every count-moving engine now routes through.
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(min_value=0, max_value=200), min_size=1,
                max_size=200))
def test_bucket_permutation_is_a_bijection(degs):
    """The bucket-grouping permutation hits every row exactly once; the
    -1 entries are pure padding; every row lands in the bucket whose
    width covers its degree."""
    from repro.core.aggregate_sampler import bucket_of, build_layout
    deg = np.asarray(degs, np.int32)
    md = max(int(deg.max()), 1)
    layout, perm = build_layout(deg, md)
    real = perm[perm >= 0]
    assert sorted(real.tolist()) == list(range(len(deg)))
    assert (perm >= -1).all() and (perm < len(deg)).all()
    starts = np.asarray(layout.row_starts)
    b_of = bucket_of(deg)
    for b, (start, cap, w) in enumerate(
            zip(layout.row_starts, layout.caps, layout.widths)):
        rows = perm[start:start + cap]
        rows = rows[rows >= 0]
        assert (b_of[rows] == b).all()
        assert (deg[rows] <= w).all()        # chain covers the whole row


@given(st.integers(min_value=1, max_value=5).flatmap(lambda s: st.tuples(
           st.just(s),
           st.lists(st.integers(min_value=0, max_value=60),
                    min_size=s * 2, max_size=s * 8))))
def test_bucketed_adjacency_roundtrips_flat_csr(case):
    """The flat bucketed neighbor table is a pure re-layout: reading back
    through the permutation reproduces each row's first deg slots of the
    padded adjacency bit-exactly."""
    from repro.core.aggregate_sampler import (build_layout_sharded,
                                              bucketize_adjacency)
    shards, degs = case
    n_loc = len(degs) // shards
    deg = np.asarray(degs[:n_loc * shards], np.int32).reshape(shards, n_loc)
    md = max(int(deg.max()), 1)
    rng = np.random.default_rng(0)
    nbr = rng.integers(0, 1000, size=(shards, n_loc, md)).astype(np.int32)
    for p in range(shards):
        for r in range(n_loc):
            nbr[p, r, deg[p, r]:] = 0          # padding slots
    layout, perm = build_layout_sharded(deg, md)
    flat = bucketize_adjacency(nbr, perm, layout)
    assert flat.shape == (shards, layout.total_edges)
    s_rows, s_edges = 0, 0
    for cap, w in zip(layout.caps, layout.widths):
        for p in range(shards):
            for i in range(cap):
                r = perm[p, s_rows + i]
                blk = flat[p, s_edges + i * w: s_edges + (i + 1) * w]
                if r < 0:
                    np.testing.assert_array_equal(blk, 0)
                else:
                    d = deg[p, r]
                    np.testing.assert_array_equal(blk[:d], nbr[p, r, :d])
        s_rows += cap
        s_edges += cap * w


@given(st.integers(min_value=1, max_value=7),
       st.integers(min_value=0, max_value=2**20),
       st.integers(min_value=0, max_value=2**16))
def test_residual_zero_at_bucket_boundary_degrees(k, count, seed):
    """Conservation (residual == 0) exactly at the bucket-boundary
    degrees d = 2^k (last row of bucket k) and d = 2^k + 1 (first row of
    bucket k+1), where an off-by-one in widths would leak mass."""
    from repro.core.aggregate_sampler import (build_layout, sample_buckets)
    degs = np.asarray([2 ** k, 2 ** k + 1, 1, 0], np.int32)
    md = int(degs.max())
    layout, perm = build_layout(degs, md)
    counts = jnp.asarray([count, count, seed % 97, 3], jnp.int32)
    rid = jnp.arange(4, dtype=jnp.int32)
    kw = jnp.asarray(np.array([seed, seed ^ 0xABCDEF], np.uint32))
    samples, occ, residual = sample_buckets(
        counts, jnp.asarray(degs), rid, kw, jnp.asarray(perm), layout,
        eps=0.2, use_pallas=False)
    assert int(residual) == 0
    total = sum(int(T.sum()) for _, T in samples)
    assert total == int(counts.sum())


@given(st.integers(min_value=1, max_value=2**16))
def test_pagerank_estimate_near_normalized(seed):
    """pi_tilde sums to ~1 (unbiased estimator of a distribution)."""
    from repro.core import simple_pagerank
    from repro.graphs import erdos_renyi
    g = erdos_renyi(48, 4.0, seed=seed % 7)
    res = simple_pagerank(g, 0.3, walks_per_node=60,
                          key=jax.random.PRNGKey(seed))
    assert 0.9 < float(res.pi.sum()) < 1.1
