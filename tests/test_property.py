"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import engine_counts
from repro.core.graph import from_edges, padded_adjacency
from repro.kernels.histogram import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.models.moe import _rank_within
from repro.train.optimizer import dequantize_blockwise, quantize_blockwise

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(st.lists(st.integers(min_value=-1, max_value=49), min_size=1,
                max_size=400),
       st.integers(min_value=1, max_value=50))
def test_histogram_matches_ref(ids, n):
    ids = jnp.asarray(ids, jnp.int32)
    np.testing.assert_array_equal(np.asarray(histogram(ids, n)),
                                  np.asarray(histogram_ref(ids, n)))


@given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                max_size=300))
def test_rank_within_is_a_ranking(ids):
    ids_j = jnp.asarray(ids, jnp.int32)
    rank = np.asarray(_rank_within(ids_j))
    for v in set(ids):
        ranks_v = sorted(rank[np.asarray(ids) == v].tolist())
        assert ranks_v == list(range(len(ranks_v)))  # 0..k-1, no dup/gap


@given(st.integers(min_value=1, max_value=2**20))
def test_quantize_roundtrip_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    bound = np.asarray(jnp.abs(x.reshape(-1, 128)).max(axis=1)) / 127.0
    err = np.asarray(jnp.abs((x - back).reshape(-1, 128)).max(axis=1))
    assert (err <= bound * 0.51 + 1e-6).all()


@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=0, max_value=100),
       st.integers(min_value=0, max_value=2**16))
def test_multinomial_split_conserves(deg, count, seed):
    """Binomial-chain multinomial: total out == total in, any degree."""
    degs = jnp.asarray([deg, 1, 3], jnp.int32)
    counts = jnp.asarray([count, 5, 0], jnp.int32)
    T, rem = engine_counts._multinomial_split(
        jax.random.PRNGKey(seed), counts, degs, int(degs.max()))
    assert int(rem.sum()) == 0
    np.testing.assert_array_equal(np.asarray(T.sum(axis=1)),
                                  np.asarray(counts))


@given(st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                min_size=1, max_size=100))
def test_csr_total_degree(edges):
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = from_edges(src, dst, 20, undirected=False, dedup=True)
    assert int(np.asarray(g.out_deg).sum()) == g.m
    nbr, valid = padded_adjacency(g)
    assert int(np.asarray(valid).sum()) == g.m


@given(st.integers(min_value=1, max_value=2**16))
def test_pagerank_estimate_near_normalized(seed):
    """pi_tilde sums to ~1 (unbiased estimator of a distribution)."""
    from repro.core import simple_pagerank
    from repro.graphs import erdos_renyi
    g = erdos_renyi(48, 4.0, seed=seed % 7)
    res = simple_pagerank(g, 0.3, walks_per_node=60,
                          key=jax.random.PRNGKey(seed))
    assert 0.9 < float(res.pi.sum()) < 1.1
