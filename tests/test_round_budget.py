"""Round-budget regressions for the 3-phase engines.

The bug this pins: the directed engine's report phase used to take ~P
drain rounds at P shards (7 at 8 shards vs 2 at 2 shards) because its
lane cap was computed from the per-home pool *maximum* rather than the
worst-case resident count — the fixed `_lane_cap` rule plus count
aggregation removed the phase outright. Under Lemma 1 the coupon
summaries are home-local (coupons never migrate), so:

  report_rounds == 0   (no report phase exists any more)
  phase3_rounds == 1   (counting is ONE aggregated exchange, not a replay)
  phase1_rounds <= lam (one round per short-walk step opportunity)

and none of these budgets may grow with the shard count.
"""
import json

import pytest

from conftest import run_forced_devices

from repro.core.distributed_improved import _lane_cap


# ---------------------------------------------------------------------------
# _lane_cap: the single home of the route_cap >= ceil(W/P) rule
# ---------------------------------------------------------------------------

def test_lane_cap_uses_ceil_division():
    # W % P != 0 must round UP (floor division was the original under-size)
    assert _lane_cap(None, 10, 4, floor=1) == 3
    assert _lane_cap(None, 12, 4, floor=1) == 3
    assert _lane_cap(None, 13, 4, floor=1) == 4


def test_lane_cap_floor_and_explicit_override():
    assert _lane_cap(None, 8, 4) == 64          # floor dominates tiny loads
    assert _lane_cap(100, 300, 4) == 100        # explicit cap >= need: kept


def test_lane_cap_rejects_undersized_override():
    with pytest.raises(AssertionError):
        _lane_cap(2, 100, 4)                    # 2 < ceil(100/4)


# ---------------------------------------------------------------------------
# engine round budgets must not scale with the shard count
# ---------------------------------------------------------------------------

ROUNDS_CODE = """
import json
import jax, numpy as np
from repro.graphs import directed_web, erdos_renyi
from repro.core.distributed_improved import distributed_improved_pagerank
from repro.core.distributed_directed import distributed_directed_pagerank

out = {}
g = erdos_renyi(96, 5.0, seed=1)
r = distributed_improved_pagerank(g, 0.2, walks_per_node=100,
                                  key=jax.random.PRNGKey(7))
out["imp"] = dict(p1=r.phase1_rounds, rep=r.report_rounds,
                  p3=r.phase3_rounds, lam=r.lam, dropped=r.dropped)
gd = directed_web(96, 5.0, seed=3)
rd = distributed_directed_pagerank(gd, 0.2, walks_per_node=40,
                                   key=jax.random.PRNGKey(7))
out["dir"] = dict(p1=rd.phase1_rounds, rep=rd.report_rounds,
                  p3=rd.phase3_rounds, lam=rd.lam, dropped=rd.dropped)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def rounds_at_8():
    return run_forced_devices(ROUNDS_CODE, devices=8)


def test_no_report_phase(rounds_at_8):
    # the 7-rounds-at-8-shards report blowup: the phase no longer exists
    assert rounds_at_8["imp"]["rep"] == 0
    assert rounds_at_8["dir"]["rep"] == 0


def test_counting_is_one_exchange(rounds_at_8):
    assert rounds_at_8["imp"]["p3"] == 1
    assert rounds_at_8["dir"]["p3"] == 1


def test_phase1_bounded_by_lambda(rounds_at_8):
    assert 1 <= rounds_at_8["imp"]["p1"] <= rounds_at_8["imp"]["lam"]
    assert 1 <= rounds_at_8["dir"]["p1"] <= rounds_at_8["dir"]["lam"]


def test_nothing_dropped_at_8_shards(rounds_at_8):
    assert rounds_at_8["imp"]["dropped"] == 0
    assert rounds_at_8["dir"]["dropped"] == 0
