"""Sharded Algorithm 2 engine tests (subprocess with 8 forced host devices):

* round complexity — total phase rounds grow ~sqrt(log n)/eps and stay
  strictly below the Algorithm 1 engine at equal (graph, eps, K);
* conservation invariants — per-round walk/coupon conservation and
  dropped == 0 for both distributed engines;
* the exhaustion fallback to naive distributed walking (tiny eta).

Statistical equivalence vs power iteration / the single-device engine is
covered by the cross-engine gate in `test_engine_conformance.py`.
"""
import textwrap

import pytest

# the conftest `small_graphs` fixtures, rebuilt inside the subprocess from
# the same source string (device count is process-global, so multi-device
# runs need a fresh interpreter with XLA_FLAGS set before jax import);
# Algorithm 2's Lemma-2 pools assume undirected graphs, so the directed
# fixture is dropped as out of contract
from conftest import SMALL_GRAPHS_SRC, run_forced_devices

SMALL_GRAPHS_SRC = SMALL_GRAPHS_SRC + "\ngraphs.pop('dweb')\n"


def _run(code: str, devices: int = 8, timeout: int = 1200) -> dict:
    # fixed 8-device mesh: the round-complexity comparisons assume a
    # specific shard count (CI's 1-device leg skips this file entirely)
    return run_forced_devices(code, devices=devices, timeout=timeout)


@pytest.fixture(scope="module")
def equiv():
    """One subprocess over all small_graphs: conservation payloads for the
    improved engine, plus an Algorithm 1 run. (Equivalence vs power
    iteration / single device lives in test_engine_conformance.py.)"""
    return _run(textwrap.dedent("""
        import json, jax, numpy as np
        from repro.core.distributed import distributed_pagerank
        from repro.core.distributed_improved import (
            distributed_improved_pagerank)
    """) + SMALL_GRAPHS_SRC + textwrap.dedent("""
        eps, K = 0.2, 100
        out = {}
        for name, g in graphs.items():
            rd = distributed_improved_pagerank(g, eps, K,
                                               jax.random.PRNGKey(0))
            out[name] = dict(
                shards=rd.shards, W=g.n * K,
                zeta=int(rd.zeta.sum()), eps=eps,
                dropped=rd.dropped, created=rd.coupons_created,
                used=rd.coupons_used,
                stitched=sum(r["stitched"] for r in rd.phase2_records),
                terminated=rd.terminated_by_coupon,
                tail_walks=rd.tail_walks, exhausted=rd.exhausted_walks,
                records=rd.phase2_records)
        r1 = distributed_pagerank(graphs["er"], eps, K,
                                  jax.random.PRNGKey(3))
        out["_alg1"] = dict(round_active=r1.round_active,
                            dropped=r1.dropped, W=96 * K,
                            zeta=int(r1.zeta.sum()))
        print(json.dumps(out))
    """))


def _graph_rows(equiv):
    return {k: v for k, v in equiv.items() if not k.startswith("_")}


def test_improved_conservation_invariants(equiv):
    """Per-round walk conservation through Phase 2, one-coupon-per-stitch,
    and zero buffer drops under the documented cap sizing rule."""
    for name, r in _graph_rows(equiv).items():
        assert r["dropped"] == 0, name
        # unbiased estimator: total visits ~ W/eps
        expect = r["W"] / r["eps"]
        assert abs(r["zeta"] - expect) / expect < 0.07, (name, r["zeta"])
        # every Phase-2 superstep retires exactly the walks it terminated
        # or sent to the fallback: active_t = active_{t-1} - retired_t
        active_prev = r["W"]
        for t, rec in enumerate(r["records"]):
            retired = rec["terminated"] + rec["exhausted"]
            assert rec["active"] == active_prev - retired, (name, t, rec)
            active_prev = rec["active"]
        assert active_prev == 0, name
        # walk conservation at Phase-2 exit: W = terminated + tail
        assert r["terminated"] + r["tail_walks"] == r["W"], name
        assert r["tail_walks"] == r["exhausted"], name
        # coupon conservation: each stitch consumed one distinct coupon
        assert r["stitched"] == r["used"], name
        assert r["used"] <= r["created"], name


def test_alg1_conservation_invariants(equiv):
    """Algorithm 1 engine: walks only terminate (active non-increasing
    from W down to 0) and no buffer overflows."""
    r = equiv["_alg1"]
    assert r["dropped"] == 0
    active = r["round_active"]
    assert active[0] <= r["W"]
    assert all(a >= b for a, b in zip(active, active[1:]))
    assert active[-1] == 0
    # unbiased estimator sanity on the same run
    expect = r["W"] / 0.2
    assert abs(r["zeta"] - expect) / expect < 0.07


def test_exhaustion_fallback():
    """eta=1 starves the coupon pools: most walks must fall back to naive
    distributed walking, and the estimate must stay accurate."""
    r = _run(textwrap.dedent("""
        import json, jax
        from repro.core import l1_error, normalized, power_iteration
        from repro.core.distributed_improved import (
            distributed_improved_pagerank)
        from repro.graphs import barabasi_albert
        g = barabasi_albert(96, 3, seed=2)
        pi_ref, _, _ = power_iteration(g, 0.2)
        res = distributed_improved_pagerank(g, 0.2, 50,
                                            jax.random.PRNGKey(0), eta=1)
        print(json.dumps(dict(
            exhausted=res.exhausted_walks, used=res.coupons_used,
            created=res.coupons_created, dropped=res.dropped,
            conserved=res.terminated_by_coupon + res.tail_walks == 96 * 50,
            l1=l1_error(normalized(res.pi), pi_ref))))
    """))
    assert r["exhausted"] > 0          # the fallback path really ran
    assert r["used"] == r["created"]   # starved pools are fully consumed
    assert r["dropped"] == 0
    assert r["conserved"]
    assert r["l1"] < 0.2


def test_round_complexity_sqrt_log_n():
    """Total phase rounds track sqrt(log n)/eps and stay strictly below
    the Algorithm 1 engine's rounds at equal (graph, eps, K)."""
    r = _run(textwrap.dedent("""
        import json, math, jax
        from repro.core.distributed import distributed_pagerank
        from repro.core.distributed_improved import (
            distributed_improved_pagerank)
        from repro.graphs import erdos_renyi
        # K large enough that Algorithm 1's max-over-W geometric walk
        # length dominates Algorithm 2's fixed phase overhead + small tail
        eps, K = 0.2, 100
        out = []
        for n in (64, 256, 1024):
            g = erdos_renyi(n, 6.0, seed=3)
            ri = distributed_improved_pagerank(g, eps, K,
                                               jax.random.PRNGKey(0))
            r1 = distributed_pagerank(g, eps, K, jax.random.PRNGKey(1))
            out.append(dict(n=n, imp=ri.rounds, alg1=r1.rounds,
                            norm=ri.rounds / (math.sqrt(math.log(n)) / eps),
                            dropped=ri.dropped))
        print(json.dumps(out))
    """), timeout=1800)
    for row in r:
        assert row["dropped"] == 0, row
        assert row["imp"] < row["alg1"], row   # the paper's headline win
    # rounds / (sqrt(log n)/eps) stays in a constant band while log n
    # grows 5x — i.e. growth is ~sqrt(log n)/eps, not log n/eps
    norms = [row["norm"] for row in r]
    assert max(norms) / min(norms) < 2.0, norms
