"""Count-aggregated distributed engine (the §Perf Lemma-1-on-the-wire
optimization): correctness vs power iteration, payload-flatness in K,
packed-lane exactness. Runs in a subprocess with 8 forced host devices."""
import json
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_count_engine_correct_and_flat_payload():
    r = _run(textwrap.dedent("""
        import json, jax, numpy as np
        from repro.core import power_iteration, l1_error, normalized
        from repro.core.distributed_counts import distributed_pagerank_counts
        from repro.graphs import erdos_renyi
        g = erdos_renyi(200, 6.0, seed=3)
        pi_ref, _, _ = power_iteration(g, 0.2)
        out = {}
        for K in (50, 200):
            res = distributed_pagerank_counts(g, 0.2, K, jax.random.PRNGKey(1))
            out[str(K)] = dict(
                a2a=res.a2a_bytes_total, overflow=res.overflow,
                l1=l1_error(normalized(res.pi), pi_ref),
                zeta=int(res.zeta.sum()), rounds=res.rounds)
        print(json.dumps(out))
    """))
    for K in ("50", "200"):
        assert r[K]["overflow"] == 0
        assert r[K]["l1"] < 0.12
        expected = 200 * int(K) / 0.2
        assert abs(r[K]["zeta"] - expected) / expected < 0.06
    # Lemma-1 wire: 4x the walks costs < 1.6x the bytes (vs 4x for
    # per-walk routing)
    assert r["200"]["a2a"] < 1.6 * r["50"]["a2a"], (r["50"], r["200"])


def test_packed_lanes_exact():
    r = _run(textwrap.dedent("""
        import json, jax, numpy as np
        from repro.core.distributed_counts import distributed_pagerank_counts
        from repro.graphs import barabasi_albert
        g = barabasi_albert(120, 3, seed=1)
        a = distributed_pagerank_counts(g, 0.25, 80, jax.random.PRNGKey(2),
                                        packed=False)
        b = distributed_pagerank_counts(g, 0.25, 80, jax.random.PRNGKey(2),
                                        packed=True)
        print(json.dumps(dict(
            equal=bool(np.array_equal(np.asarray(a.zeta), np.asarray(b.zeta))),
            ratio=a.a2a_bytes_total / max(b.a2a_bytes_total, 1))))
    """))
    assert r["equal"] is True            # packing is bit-exact
    assert 1.9 < r["ratio"] < 2.1        # exactly half the wire bytes
