"""Regression tests for the aggregate sampler's integer exactness.

The bug class (same as the PR-6 estimator fix, one layer down): the old
per-round draws ran `jax.random.binomial(k, counts.astype(float32), p)`.
float32 is integer-exact only up to 2**24, so a hub row whose aggregate
coupon count passed ~16.7M silently truncated — coupons created or
destroyed before the draw even happened. The shared sampler
(`kernels/multinomial_rows`) keeps counts in int32 end to end: the
Binomial endpoints p == 0 and p == 1 are computed in integer arithmetic
and every chain draw is clipped to the integer remainder, so conservation
(T.sum() == counts) holds bit-exactly at ANY count magnitude. Only the
*marginal means* of the normal branch run through float32 (a ~1e-7
relative statistical error, never a leak).
"""
import jax.numpy as jnp
import numpy as np

from repro.kernels.multinomial_rows._math import key_words, sample_rows_math
from repro.kernels.multinomial_rows.ref import multinomial_rows_ref

KW = (np.uint32(0x12345678), np.uint32(0x9ABCDEF0))


def _sample(counts, deg, *, eps=0.2, width=None):
    counts = jnp.asarray(counts, jnp.int32)
    deg = jnp.asarray(deg, jnp.int32)
    width = width or max(int(deg.max()), 1)
    rid = jnp.arange(counts.shape[0], dtype=jnp.int32)
    return sample_rows_math(counts, deg, rid, KW[0], KW[1],
                            eps=float(eps), width=width)


def test_float32_would_truncate_but_sampler_conserves():
    # the motivating rounding: 2**24 and 2**24 + 1 collide in float32 —
    # the old astype(f32) draw path could not tell these rows apart
    assert np.float32(2 ** 24) == np.float32(2 ** 24 + 1)
    counts = [2 ** 24, 2 ** 24 + 1, 2 ** 30, 2 ** 31 - 1]
    T = np.asarray(_sample(counts, [3, 3, 5, 2], width=8))
    # bit-exact conservation per row, far beyond float32 integer range
    np.testing.assert_array_equal(T.sum(axis=1), np.asarray(counts))
    # and the two f32-colliding rows stay distinct in total
    assert T[1].sum() - T[0].sum() == 1


def test_endpoint_probabilities_are_integer_exact():
    big = 2 ** 26 + 13
    # eps = 1: every coupon terminates, none leak to edges
    T1 = np.asarray(_sample([big], [4], eps=1.0, width=4))
    assert T1[0, 0] == big and T1[0, 1:].sum() == 0
    # deg = 1: the single out-edge draws p == 1 -> exactly the survivors
    T2 = np.asarray(_sample([big], [1], eps=0.25, width=1))
    assert T2[0, 0] + T2[0, 1] == big


def test_dangling_rows_terminate_whole():
    big = 2 ** 28 + 5
    T = np.asarray(_sample([big, 7, 0], [0, 0, 0], width=3))
    np.testing.assert_array_equal(T[:, 0], [big, 7, 0])
    assert T[:, 1:].sum() == 0


def test_ref_kernel_conserves_across_magnitudes():
    rng = np.random.default_rng(0)
    counts = np.concatenate([
        rng.integers(0, 2000, size=64),
        np.array([2 ** 24, 2 ** 24 + 1, 2 ** 27 + 3, 2 ** 30])],
    ).astype(np.int32)
    deg = rng.integers(0, 9, size=counts.shape[0]).astype(np.int32)
    rid = np.arange(counts.shape[0], dtype=np.int32)
    T = np.asarray(multinomial_rows_ref(
        jnp.asarray(counts), jnp.asarray(deg), jnp.asarray(rid),
        jnp.asarray(np.stack(KW)), eps=0.2, width=8))
    np.testing.assert_array_equal(T.sum(axis=1), counts)
    # nothing lands beyond a row's degree
    for j in range(8):
        assert np.all(T[deg <= j, 1 + j] == 0)
