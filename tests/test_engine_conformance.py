"""Canonical cross-engine conformance gate.

Every engine in the ROADMAP matrix — 4 single-device (Algorithm 1 walk and
count state, Algorithm 2, Section-5 directed/LOCAL) and 4 distributed
shard_map realizations — is run against power iteration on the shared
small-graph fixtures under ONE tolerance policy:

  * l1(normalized(pi), power_iteration) < L1_TOL
  * estimator mass: |sum(pi) - 1| < MASS_TOL (unbiasedness)
  * top-10 overlap >= TOPK_MIN on skewed fixtures (ranking quality)
  * transport counters clean: dropped == 0 / overflow == 0 where the
    engine reports them (an exact run, no silent truncation)

Each engine runs on the fixtures its model covers: the Algorithm-1 and
Section-5 engines are direction-agnostic and take every fixture; the
Algorithm-2 engines require the undirected Lemma-2 degree bound, so they
take the undirected ones. The batched Personalized-PageRank engine is
validated per query against the `exact_ppr` dense linear solve (PPR has
no single power-iteration reference — the stationary vector depends on
each query's source distribution) under the SAME L1/mass/top-10
thresholds. The distributed half runs in one subprocess
(device count is process-global) honoring REPRO_TEST_DEVICES (default 8,
CI also runs 1 to cover the single-shard fallback paths); it additionally
checks the sharded Section-5 engine against its single-device twin
(cross-engine statistical match) and its per-round coupon conservation.

This suite replaces the per-engine copy-pasted equivalence checks that
previously lived in test_pagerank_correctness / test_distributed*.
"""
import textwrap
import zlib

import jax
import numpy as np
import pytest

from repro.core import (directed_local_pagerank, improved_pagerank, l1_error,
                        normalized, power_iteration, simple_pagerank,
                        topk_overlap)

EPS = 0.2
K = 100          # walks per node (Monte Carlo sample size)
K_DIR = 40       # sharded Section-5: uniform pools scale ~K*log^2, so use a
                 # smaller (still ample: l1 ~ 1/sqrt(nK)) sample to keep the
                 # coupon pool tables (and the single-device twin) CI-sized;
                 # wire/lanes no longer care — counts aggregate per vertex
L1_TOL = 0.15
MASS_TOL = 0.10
TOPK_MIN = 0.6

UNDIRECTED = ("ring", "grid", "er", "ba", "ba_hub")
ALL_GRAPHS = UNDIRECTED + ("dweb",)
SKEWED = ("er", "ba", "ba_hub", "dweb")  # fixtures where a top-10 ranking
                                         # is meaningful


def check_policy(name, pi, pi_ref):
    """The single tolerance policy, applied to every (engine, graph) cell."""
    pi = np.asarray(pi, dtype=np.float64)
    assert abs(pi.sum() - 1.0) < MASS_TOL, (name, pi.sum())
    assert l1_error(normalized(pi), pi_ref) < L1_TOL, \
        (name, l1_error(normalized(pi), pi_ref))
    if name[1] in SKEWED:
        assert topk_overlap(pi, np.asarray(pi_ref), k=10) >= TOPK_MIN, name


@pytest.fixture(scope="module")
def pi_refs(small_graphs):
    return {name: power_iteration(g, EPS)[0]
            for name, g in small_graphs.items()}


# ---------------------------------------------------------------------------
# single-device engines (in-process; run under 1 or 8 forced devices)
# ---------------------------------------------------------------------------

SINGLE_ENGINES = {
    "alg1_walks": (ALL_GRAPHS, lambda g, k: simple_pagerank(
        g, EPS, walks_per_node=K, key=k, engine="walks").pi),
    "alg1_counts": (ALL_GRAPHS, lambda g, k: simple_pagerank(
        g, EPS, walks_per_node=K, key=k, engine="counts").pi),
    "alg2_improved": (UNDIRECTED, lambda g, k: improved_pagerank(
        g, EPS, walks_per_node=K, key=k).pi),
    "sec5_directed": (ALL_GRAPHS, lambda g, k: directed_local_pagerank(
        g, EPS, walks_per_node=K, key=k).pi),
}

SINGLE_CASES = [(e, g) for e, (graphs, _) in sorted(SINGLE_ENGINES.items())
                for g in graphs]


@pytest.mark.parametrize("engine,graph", SINGLE_CASES,
                         ids=[f"{e}-{g}" for e, g in SINGLE_CASES])
def test_single_device_conformance(engine, graph, small_graphs, pi_refs):
    _, run = SINGLE_ENGINES[engine]
    seed = zlib.crc32(f"{engine}-{graph}".encode())  # deterministic per cell
    pi = run(small_graphs[graph], jax.random.PRNGKey(seed))
    check_policy((engine, graph), pi, pi_refs[graph])


# ---------------------------------------------------------------------------
# batched PPR engine (in-process, runs on however many devices the CI leg
# forces) — per-query cells against the exact_ppr dense solve
# ---------------------------------------------------------------------------

PPR_QUERIES = [([0, 5], None), ([17], None), ([3, 40], [0.8, 0.2])]
PPR_WALKS = 12_000  # per query; l1 ~ 1/sqrt(n*W) leaves ~4x headroom


@pytest.fixture(scope="module")
def batched_ppr(small_graphs):
    from repro.core.personalized_batch import batched_personalized_pagerank
    return batched_personalized_pagerank(
        small_graphs["ba"], EPS, PPR_QUERIES, PPR_WALKS,
        jax.random.PRNGKey(21))


@pytest.mark.parametrize("qi", range(len(PPR_QUERIES)),
                         ids=[f"q{i}" for i in range(len(PPR_QUERIES))])
def test_batched_ppr_conformance(qi, small_graphs, batched_ppr):
    from repro.core.personalized import exact_ppr
    assert batched_ppr.dropped == 0 and batched_ppr.admit_dropped == 0
    sources, weights = PPR_QUERIES[qi]
    ref = normalized(exact_ppr(small_graphs["ba"], EPS, sources,
                               weights=weights))
    check_policy((f"batched_ppr_q{qi}", "ba"), batched_ppr.ppr[qi], ref)


# ---------------------------------------------------------------------------
# distributed engines (one subprocess; XLA device count is process-global)
# ---------------------------------------------------------------------------

# the conftest `small_graphs` fixtures, rebuilt inside the subprocess from
# the same source string (device count is process-global)
from conftest import SMALL_GRAPHS_SRC, run_forced_devices

DIST_CODE = textwrap.dedent("""
    import json, jax, numpy as np
    from repro.core import (directed_local_pagerank, l1_error, normalized,
                            power_iteration, topk_overlap)
    from repro.core.distributed import distributed_pagerank
    from repro.core.distributed_counts import distributed_pagerank_counts
    from repro.core.distributed_directed import distributed_directed_pagerank
    from repro.core.distributed_improved import distributed_improved_pagerank
""") + SMALL_GRAPHS_SRC + textwrap.dedent("""
    EPS, K, K_DIR = %(eps)r, %(k)d, %(k_dir)d
    UNDIRECTED = %(undirected)r

    def cell(pi, ref, **extra):
        pi = np.asarray(pi, dtype=np.float64)
        return dict(mass=float(pi.sum()),
                    l1=l1_error(normalized(pi), ref),
                    topk=topk_overlap(pi, np.asarray(ref), k=10), **extra)

    out = {"walks": {}, "counts": {}, "improved": {}, "directed": {}}
    refs = {n: power_iteration(g, EPS)[0] for n, g in graphs.items()}
    for name, g in graphs.items():
        # Alg 1 walk engine: on the directed hub fixture the 2*W/P CONGEST
        # cap drops walks (no degree bound ties load to a shard), so give
        # it the worst-case W-sized buffer there.
        cap = g.n * K + 8 * 64 if name == "dweb" else None
        r = distributed_pagerank(g, EPS, K, jax.random.PRNGKey(10), cap=cap)
        out["walks"][name] = cell(r.pi, refs[name], dropped=r.dropped)
        rc = distributed_pagerank_counts(g, EPS, K, jax.random.PRNGKey(11))
        out["counts"][name] = cell(rc.pi, refs[name], dropped=rc.overflow)
        if name in UNDIRECTED:
            ri = distributed_improved_pagerank(g, EPS, K,
                                               jax.random.PRNGKey(12))
            out["improved"][name] = cell(ri.pi, refs[name],
                                         dropped=ri.dropped)

    # Section-5 sharded engine on the directed fixture, plus its
    # single-device twin (same K) for the cross-engine statistical match.
    g = graphs["dweb"]
    rd = distributed_directed_pagerank(g, EPS, K_DIR, jax.random.PRNGKey(13))
    rs = directed_local_pagerank(g, EPS, walks_per_node=K_DIR,
                                 key=jax.random.PRNGKey(14))
    out["directed"]["dweb"] = cell(
        rd.pi, refs["dweb"], dropped=rd.dropped,
        l1_cross=l1_error(normalized(rd.pi), normalized(rs.pi)),
        n=g.n, W=g.n * K_DIR, zeta=int(rd.zeta.sum()), eps=EPS,
        shards=rd.shards, lam=rd.lam, uniform_budget=rd.uniform_budget,
        created=rd.coupons_created, used=rd.coupons_used,
        stitched=sum(r["stitched"] for r in rd.phase2_records),
        terminated=rd.terminated_by_coupon, tail_walks=rd.tail_walks,
        exhausted=rd.exhausted_walks, records=rd.phase2_records)
    print(json.dumps(out))
""") % dict(eps=EPS, k=K, k_dir=K_DIR, undirected=UNDIRECTED)

DIST_CASES = ([("walks", g) for g in ALL_GRAPHS]
              + [("counts", g) for g in ALL_GRAPHS]
              + [("improved", g) for g in UNDIRECTED]
              + [("directed", "dweb")])


@pytest.fixture(scope="module")
def dist_payload():
    return run_forced_devices(DIST_CODE)


@pytest.mark.parametrize("engine,graph", DIST_CASES,
                         ids=[f"{e}-{g}" for e, g in DIST_CASES])
def test_distributed_conformance(engine, graph, dist_payload):
    r = dist_payload[engine][graph]
    name = (f"dist_{engine}", graph)
    assert abs(r["mass"] - 1.0) < MASS_TOL, (name, r["mass"])
    assert r["l1"] < L1_TOL, (name, r["l1"])
    if graph in SKEWED:
        assert r["topk"] >= TOPK_MIN, (name, r["topk"])
    assert r["dropped"] == 0, name


def test_directed_cross_engine_and_conservation(dist_payload):
    """Sharded Section-5 vs its single-device twin, plus the engine's
    conservation invariants: per-round walk retirement bookkeeping,
    one-distinct-coupon-per-stitch, unbiased total visit mass."""
    r = dist_payload["directed"]["dweb"]
    # two Monte Carlo estimates of the same vector
    assert r["l1_cross"] < 2 * L1_TOL, r["l1_cross"]
    # unbiased estimator: total visits ~ W/eps (dweb has no dangling nodes)
    expect = r["W"] / r["eps"]
    assert abs(r["zeta"] - expect) / expect < 0.07, r["zeta"]
    # every Phase-2 superstep retires exactly the walks it terminated or
    # sent to the fallback
    active_prev = r["W"]
    for t, rec in enumerate(r["records"]):
        retired = rec["terminated"] + rec["exhausted"]
        assert rec["active"] == active_prev - retired, (t, rec)
        active_prev = rec["active"]
    assert active_prev == 0
    # walk conservation at Phase-2 exit, and one distinct coupon per stitch
    assert r["terminated"] + r["tail_walks"] == r["W"]
    assert r["tail_walks"] == r["exhausted"]
    assert r["stitched"] == r["used"]
    assert r["used"] <= r["created"]
    # Section-5 telemetry: uniform per-node budget actually uniform
    assert r["created"] == r["n"] * r["uniform_budget"]
