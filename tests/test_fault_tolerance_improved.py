"""Fault-injection regression coverage for the 3-phase phase-machine.

Kill the supervised engines at a phase boundary and mid-Phase-2 — for both
`improved` (Algorithm 2) and `directed` (Section 5) — and at mid-run for
the counts engine: the recovered run must return bit-identical `zeta`/`pi`
and identical round/wire telemetry vs an unfailed run. Phase-3 already
depends on deterministic re-execution of Phase 1, so exactness is a hard
invariant here, not a statistical one. A cross-process-style kill
(max_restarts=0 leaves snapshots behind) followed by `resume=True` must
also reproduce the unfailed run bit-exactly.

The engine runs live in one subprocess honoring REPRO_TEST_DEVICES (the
device count is process-global); the `StageSchedule`/JSON-leaf machinery
is additionally unit-tested in-process, jax-free.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_forced_devices

ENGINE_CODE = textwrap.dedent("""
    import json, tempfile, jax, numpy as np
    from repro.core.distributed_counts import distributed_pagerank_counts
    from repro.core.distributed_directed import distributed_directed_pagerank
    from repro.core.distributed_improved import distributed_improved_pagerank
    from repro.graphs import directed_web, erdos_renyi
    from repro.runtime import SimulatedFailure

    def telemetry(r):
        return dict(rounds=r.rounds, p1=r.phase1_rounds,
                    rep=r.report_rounds, p2=r.phase2_rounds,
                    p3=r.phase3_rounds, tail=r.tail_rounds,
                    wire=dict(r.a2a_bytes_by_phase), dropped=r.dropped,
                    waited=r.waited, used=r.coupons_used,
                    created=r.coupons_created, tail_walks=r.tail_walks,
                    exhausted=r.exhausted_walks,
                    terminated=r.terminated_by_coupon,
                    records=r.phase2_records)

    out = {}
    CASES = dict(
        improved=(distributed_improved_pagerank,
                  erdos_renyi(64, 5.0, seed=1), 40, 0),
        directed=(distributed_directed_pagerank,
                  directed_web(64, 5.0, seed=3), 20, 1))
    for name, (engine, g, K, seed) in CASES.items():
        ref = engine(g, 0.25, K, jax.random.PRNGKey(seed))
        # global rounds span the phases: fail once exactly at the
        # phase1 -> report boundary, once mid-Phase-2
        boundary = ref.phase1_rounds
        mid_p2 = (ref.phase1_rounds + ref.report_rounds
                  + max(ref.phase2_rounds // 2, 1))
        with tempfile.TemporaryDirectory() as d:
            rec = engine(g, 0.25, K, jax.random.PRNGKey(seed),
                         checkpoint_dir=d, fail_at=[boundary, mid_p2],
                         checkpoint_every=3)
        out[name] = dict(
            restarts=rec.restarts, ckpts=rec.checkpoints_written,
            fail_at=[boundary, mid_p2],
            zeta_equal=bool(np.array_equal(np.asarray(ref.zeta),
                                           np.asarray(rec.zeta))),
            pi_equal=bool(np.array_equal(np.asarray(ref.pi),
                                         np.asarray(rec.pi))),
            ref_tel=telemetry(ref), rec_tel=telemetry(rec))

    # cross-process-style kill: max_restarts=0 turns the first injected
    # failure fatal (snapshots survive), then a fresh engine call resumes
    # cold from the latest stage-tagged snapshot
    engine, g, K, seed = CASES["improved"]
    ref = engine(g, 0.25, K, jax.random.PRNGKey(seed))
    mid_p2 = (ref.phase1_rounds + ref.report_rounds
              + max(ref.phase2_rounds // 2, 1))
    with tempfile.TemporaryDirectory() as d:
        died = False
        try:
            engine(g, 0.25, K, jax.random.PRNGKey(seed), checkpoint_dir=d,
                   fail_at=[mid_p2], checkpoint_every=3, max_restarts=0)
        except SimulatedFailure:
            died = True
        res = engine(g, 0.25, K, jax.random.PRNGKey(seed),
                     checkpoint_dir=d, resume=True, checkpoint_every=3)
    out["resume"] = dict(
        died=died,
        zeta_equal=bool(np.array_equal(np.asarray(ref.zeta),
                                       np.asarray(res.zeta))),
        telemetry_equal=telemetry(ref) == telemetry(res))

    # counts engine (single-stage schedule) under the same supervisor
    g = erdos_renyi(64, 5.0, seed=1)
    refc = distributed_pagerank_counts(g, 0.25, 40, jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as d:
        recc = distributed_pagerank_counts(
            g, 0.25, 40, jax.random.PRNGKey(2), checkpoint_dir=d,
            fail_at=[5], checkpoint_every=3)
    out["counts"] = dict(
        restarts=recc.restarts,
        zeta_equal=bool(np.array_equal(np.asarray(refc.zeta),
                                       np.asarray(recc.zeta))),
        rounds_equal=refc.rounds == recc.rounds,
        a2a_equal=refc.a2a_bytes_total == recc.a2a_bytes_total)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def payload():
    return run_forced_devices(ENGINE_CODE)


@pytest.mark.parametrize("engine", ["improved", "directed"])
def test_recovery_bit_exact(engine, payload):
    """Phase-boundary + mid-Phase-2 kills: the recovered run is the
    unfailed run, bit for bit, telemetry included."""
    r = payload[engine]
    assert r["restarts"] == 2, r
    assert r["zeta_equal"] and r["pi_equal"], r
    assert r["rec_tel"] == r["ref_tel"], (engine, r["fail_at"])
    assert r["rec_tel"]["dropped"] == 0, r
    assert r["ckpts"] >= 2, r  # round-0 plus at least one periodic


def test_cold_resume_after_kill(payload):
    """max_restarts=0 kill leaves snapshots; resume=True completes the run
    and matches the unfailed run exactly."""
    r = payload["resume"]
    assert r["died"], r
    assert r["zeta_equal"], r
    assert r["telemetry_equal"], r


def test_counts_recovery_bit_exact(payload):
    r = payload["counts"]
    assert r["restarts"] == 1, r
    assert r["zeta_equal"] and r["rounds_equal"] and r["a2a_equal"], r


# ---------------------------------------------------------------------------
# in-process units: schedule composition + snapshot JSON leaves (jax-free)
# ---------------------------------------------------------------------------

def test_stage_schedule_orders_stages_and_runs_transitions():
    from repro.runtime import Stage, StagedState, StageSchedule

    log = []

    def stepper(tag, steps):
        def step(ms):
            ms.host[tag] = ms.host.get(tag, 0) + 1
            log.append(tag)
            return ms, ms.host[tag] >= steps
        return step

    def transition(ms):
        log.append("switch")
        return ms

    sched = StageSchedule([Stage("a", stepper("a", 2), on_done=transition),
                           Stage("b", stepper("b", 1))])
    ms = StagedState(stage=sched.first_stage, arrays={}, host={})
    done = False
    rounds = 0
    while not done:
        ms, done = sched.step(ms)
        rounds += 1
    assert log == ["a", "a", "switch", "b"]
    assert rounds == 3
    with pytest.raises(ValueError):
        StageSchedule([Stage("x", stepper("x", 1)),
                       Stage("x", stepper("x", 1))])


def test_fresh_run_refuses_stale_snapshots(tmp_path):
    """A fresh (resume=False) run into a dir that already holds snapshots
    must refuse to start — recovering from a previous run's snapshot would
    restore foreign state, and silently wiping it would destroy that run's
    recovery points. Checkpointer.clear() is the explicit opt-out."""
    from repro.checkpoint import Checkpointer
    from repro.runtime import (Stage, StagedState, StageSchedule,
                               run_staged, staged_to_host)

    stale = StagedState(stage="s", arrays={}, host=dict(count=999))
    ck = Checkpointer(str(tmp_path))
    ck.save(50, staged_to_host(stale))

    def step(ms):
        ms.host["count"] += 1
        return ms, ms.host["count"] >= 5

    sched = StageSchedule([Stage("s", step)])

    def fresh():
        return StagedState(stage=sched.first_stage, arrays={},
                           host=dict(count=0))

    with pytest.raises(FileExistsError, match="already holds snapshots"):
        run_staged(sched, fresh(), lambda n, a: a,
                   checkpoint_dir=str(tmp_path), fail_at=[2],
                   checkpoint_every=10)
    ck.clear()
    out, restarts, _ = run_staged(sched, fresh(), lambda n, a: a,
                                  checkpoint_dir=str(tmp_path),
                                  fail_at=[2], checkpoint_every=10)
    assert restarts == 1
    assert out.host["count"] == 5     # its own trajectory, not the stale 999


def test_resume_without_checkpoint_dir_raises():
    from repro.runtime import (Stage, StagedState, StageSchedule,
                               run_staged)
    sched = StageSchedule([Stage("s", lambda ms: (ms, True))])
    ms = StagedState(stage="s", arrays={}, host={})
    with pytest.raises(ValueError, match="checkpoint_dir"):
        run_staged(sched, ms, lambda n, a: a, resume=True)


def test_resume_from_empty_dir_raises(tmp_path):
    """A typo'd --checkpoint-dir must not silently recompute from round 0:
    resume against a snapshot-less directory is an error."""
    from repro.runtime import (Stage, StagedState, StageSchedule,
                               run_staged)
    sched = StageSchedule([Stage("s", lambda ms: (ms, True))])
    ms = StagedState(stage="s", arrays={}, host={})
    with pytest.raises(FileNotFoundError, match="no snapshots"):
        run_staged(sched, ms, lambda n, a: a, resume=True,
                   checkpoint_dir=str(tmp_path / "typo"))


def test_staged_snapshot_roundtrip(tmp_path):
    from repro.checkpoint import Checkpointer
    from repro.runtime import StagedState, staged_from_host, staged_to_host

    ms = StagedState(stage="phase2",
                     arrays=dict(pos=np.arange(6, dtype=np.int32),
                                 used=np.ones((2, 3), np.int32)),
                     host=dict(rounds=7, wire=dict(phase1=40),
                               traces=[[3, 2], [0, 1]]))
    ck = Checkpointer(str(tmp_path))
    ck.save(7, staged_to_host(ms))
    flat, manifest = ck.restore()
    back = staged_from_host(flat, lambda name, arr: arr)
    assert manifest["step"] == 7
    assert back.stage == "phase2"
    assert back.host == ms.host
    assert sorted(back.arrays) == ["pos", "used"]
    np.testing.assert_array_equal(back.arrays["pos"], ms.arrays["pos"])
    np.testing.assert_array_equal(back.arrays["used"], ms.arrays["used"])
