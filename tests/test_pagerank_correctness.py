"""End-to-end correctness properties of the paper's algorithms (estimator
bias, Monte-Carlo scaling, round complexity, coupon accounting).

Engine-vs-power-iteration equivalence checks live in ONE place now — the
cross-engine gate in `test_engine_conformance.py` — not per-engine here."""
import jax

from repro.core import (exact_pagerank, improved_pagerank, l1_error,
                        normalized, power_iteration, simple_pagerank,
                        walks_per_node_for)

EPS = 0.2


def test_power_iteration_matches_eigenvector(small_graphs):
    for name, g in small_graphs.items():
        pi, err, iters = power_iteration(g, EPS)
        pi_exact = exact_pagerank(g, EPS)
        assert l1_error(pi, pi_exact) < 1e-4, name
        assert iters < 200


def test_simple_pagerank_unbiased_total_mass(small_graphs):
    """E[sum zeta] = nK/eps; empirical total within 5%."""
    g = small_graphs["ring"]
    K = 200
    res = simple_pagerank(g, EPS, walks_per_node=K, key=jax.random.PRNGKey(5))
    expected = g.n * K / EPS
    assert abs(int(res.zeta.sum()) - expected) / expected < 0.05


def test_error_decreases_with_K(small_graphs):
    g = small_graphs["ba"]
    pi_ref, _, _ = power_iteration(g, EPS)
    errs = []
    for K in (20, 80, 320):
        res = simple_pagerank(g, EPS, walks_per_node=K,
                              key=jax.random.PRNGKey(7))
        errs.append(l1_error(normalized(res.pi), pi_ref))
    assert errs[2] < errs[0], errs  # Monte Carlo error shrinks ~ 1/sqrt(K)


def test_improved_pagerank_coupon_accounting(small_graphs):
    g = small_graphs["er"]
    res = improved_pagerank(g, EPS, walks_per_node=150,
                            key=jax.random.PRNGKey(11))
    assert res.coupons_used <= res.coupons_created
    assert res.exhausted_walks == 0  # auto-eta sized generously


def test_improved_faster_than_simple_in_congest_rounds(small_graphs):
    """Theorem 2 vs Theorem 1: stitched walks need fewer CONGEST rounds."""
    g = small_graphs["er"]
    simple = simple_pagerank(g, EPS, walks_per_node=60,
                             key=jax.random.PRNGKey(13), traced=True)
    improved = improved_pagerank(g, EPS, walks_per_node=60,
                                 key=jax.random.PRNGKey(13))
    assert improved.report.congest_rounds < simple.report.congest_rounds


def test_default_K_accuracy(small_graphs):
    """K = c log n (Sec 3.2) gives whp-accurate PageRank (Avrachenkov)."""
    g = small_graphs["grid"]
    K = walks_per_node_for(g.n, EPS)
    pi_ref, _, _ = power_iteration(g, EPS)
    res = simple_pagerank(g, EPS, walks_per_node=K, key=jax.random.PRNGKey(19))
    assert l1_error(normalized(res.pi), pi_ref) < 0.10
