"""PPR query service: continuous-batching admission, cache, refresh.

Time is injected everywhere (`now=`) so TTL/refresh behavior is tested
against a controlled clock; accuracy itself is gated by the conformance
suite (tests/test_engine_conformance.py) — here one loose sanity check
keeps the served vectors anchored to the exact_ppr oracle.
"""
import jax
import numpy as np
import pytest

from repro.core import l1_error, normalized
from repro.core.personalized import exact_ppr
from repro.serve import PPRService, ResultCache
from repro.graphs import barabasi_albert


@pytest.fixture(scope="module")
def graph():
    return barabasi_albert(48, 3, seed=3)


def make_service(graph, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("walks_per_query", 800)
    kw.setdefault("eps", 0.3)
    return PPRService(graph, kw.pop("eps"), key=jax.random.PRNGKey(5), **kw)


def drive(svc, now):
    done = []
    while svc.busy:
        done.extend(svc.step(now=now))
    return done


def test_serves_batched_queries_and_caches(graph):
    svc = make_service(graph)
    r1 = svc.submit([0, 5], now=0.0)
    r2 = svc.submit([7], now=0.0)
    r3 = svc.submit([11, 2], now=0.0)   # queued: only 2 slots
    done = drive(svc, now=1.0)
    assert {r.rid for r in done} == {r1.rid, r2.rid, r3.rid}
    assert all(r.done and r.result is not None for r in (r1, r2, r3))
    assert svc.stats.admitted == 3 and svc.stats.completed == 3
    assert svc.stats.max_active_queries == 2          # batched, slot-bound
    assert svc.stats.dropped_walks == 0
    assert svc.stats.admit_dropped == 0
    # loose oracle anchor (tight gate lives in the conformance suite)
    ref = exact_ppr(graph, 0.3, [0, 5])
    assert l1_error(normalized(r1.result), normalized(ref)) < 0.3

    # cache hit: answered at submit time, bit-identical stored vector
    r4 = svc.submit([0, 5], now=2.0)
    assert r4.cached and r4.done
    assert np.array_equal(r4.result, r1.result)
    assert svc.stats.cache_hits == 1
    assert svc.stats.admitted == 3                    # no recompute
    assert not svc.busy


def test_ttl_expiry_forces_recompute(graph):
    svc = make_service(graph, ttl=10.0)
    svc.submit([1, 3], now=0.0)
    drive(svc, now=0.5)
    assert svc.stats.admitted == 1
    # inside ttl: hit; beyond ttl: evicted -> recompute
    assert svc.submit([1, 3], now=5.0).cached
    r = svc.submit([1, 3], now=50.0)
    assert not r.cached
    drive(svc, now=51.0)
    assert svc.stats.admitted == 2
    assert r.done and r.result is not None


def test_hot_source_refresh_serves_stale_and_recomputes(graph):
    svc = make_service(graph, ttl=100.0, refresh_age=5.0)
    first = svc.submit([2], now=0.0)
    drive(svc, now=0.5)
    stored_v1 = svc.cache.stored_at((first.sources, first.weights))

    hit = svc.submit([2], now=7.0)      # older than refresh_age: hot
    assert hit.cached                    # served stale, never blocked
    assert np.array_equal(hit.result, first.result)
    assert svc.stats.refreshes == 1
    assert svc.busy                      # the background refresh is queued

    # a second hot hit while a refresh is in flight does not pile up
    assert svc.submit([2], now=7.5).cached
    assert svc.stats.refreshes == 1

    done = drive(svc, now=8.0)
    assert len(done) == 1 and done[0].refresh
    assert svc.cache.stored_at((first.sources, first.weights)) > stored_v1
    # the refreshed entry now serves hits
    assert np.array_equal(svc.submit([2], now=9.0).result, done[0].result)


def test_max_pending_rejects_not_drops(graph):
    svc = make_service(graph, slots=1, max_pending=1)
    svc.submit([4], now=0.0)
    svc.submit([6], now=0.0)
    r = svc.submit([8], now=0.0)        # queue full
    assert r.rejected and r.done and r.result is None
    assert svc.stats.rejected == 1
    drive(svc, now=1.0)
    assert svc.stats.completed == 2      # the accepted ones still finish


def test_result_cache_lru_and_ttl_clock():
    c = ResultCache(max_entries=2, ttl=10.0, refresh_age=4.0)
    a, b, d = (np.array([1.0]), np.array([2.0]), np.array([3.0]))
    c.put("a", a, now=0.0)
    c.put("b", b, now=1.0)
    assert c.get("a", now=2.0) == (a, False)
    c.put("d", d, now=3.0)               # evicts LRU = "b"
    assert c.get("b", now=3.0) == (None, False)
    v, refresh = c.get("a", now=5.0)     # age 5 >= refresh_age
    assert v is a and refresh
    assert c.get("a", now=11.0) == (None, False)   # age >= ttl: evicted
    assert len(c) == 1
