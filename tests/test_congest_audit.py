"""CONGEST auditor + engine lints: unit coverage and the full-fleet gate.

The lint passes are exercised in-process on hand-built jaxprs; the full
auditor (trace every engine's stage programs, check the declared wire
budgets, cross-check static widths against runtime telemetry) runs in a
forced-8-device subprocess, exactly as the CI audit job invokes it.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp

from conftest import run_forced_devices
from repro.analysis.lint import (classify_resume, dtype_lint, rng_lint,
                                 schema_lint)

KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


# ---------------------------------------------------------------------------
# RNG-key discipline
# ---------------------------------------------------------------------------

def test_rng_lint_flags_key_reuse():
    def bad(key):
        return jax.random.uniform(key, (4,)) + jax.random.normal(key, (4,))

    findings, consumed = rng_lint(jax.make_jaxpr(bad)(KEY), where="bad")
    assert consumed >= 2
    assert any(f.severity == "violation" for f in findings)


def test_rng_lint_accepts_split_discipline():
    def good(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1, (4,)) + jax.random.normal(k2, (4,))

    findings, consumed = rng_lint(jax.make_jaxpr(good)(KEY), where="good")
    assert findings == []
    assert consumed >= 3  # the split itself + one draw per sub-key


def test_rng_lint_fold_in_derives_fresh_lineage():
    def good(key):
        a = jax.random.uniform(jax.random.fold_in(key, 1), (4,))
        b = jax.random.uniform(jax.random.fold_in(key, 2), (4,))
        return a + b

    findings, _ = rng_lint(jax.make_jaxpr(good)(KEY), where="fold")
    assert findings == []


def test_rng_lint_zero_consumption_means_rng_free():
    def pure(x):
        return x * 2

    findings, consumed = rng_lint(
        jax.make_jaxpr(pure)(jax.ShapeDtypeStruct((4,), jnp.int32)))
    assert findings == [] and consumed == 0


# ---------------------------------------------------------------------------
# dtype funnels
# ---------------------------------------------------------------------------

def test_dtype_lint_flags_overflowing_funnel():
    def f(x):
        return x.astype(jnp.float32).sum()

    cj = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((8,), jnp.int32))
    bad = [v for v in dtype_lint(cj, count_bound=2 ** 25, where="f")
           if v.severity == "violation"]
    assert len(bad) == 1 and "2^24" in bad[0].message


def test_dtype_lint_accepts_bounded_counts():
    def f(x):
        return x.astype(jnp.float32).sum()

    cj = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((8,), jnp.int32))
    assert [v for v in dtype_lint(cj, count_bound=1000)
            if v.severity == "violation"] == []
    # and with no declared bound the funnel is at most a note
    assert [v for v in dtype_lint(cj) if v.severity == "violation"] == []


# ---------------------------------------------------------------------------
# elastic schema
# ---------------------------------------------------------------------------

def test_schema_lint_both_directions():
    spec = types.SimpleNamespace(kind="vertex")
    ok = schema_lint({"s": ("a", "b")}, {"s": {"a": spec, "b": spec}})
    assert ok == []
    missing = schema_lint({"s": ("a", "b")}, {"s": {"a": spec}})
    assert len(missing) == 1 and "'b'" in missing[0].message
    dangling = schema_lint({"s": ("a",)}, {"s": {"a": spec, "ghost": spec}})
    assert len(dangling) == 1 and "'ghost'" in dangling[0].message
    nostage = schema_lint({"s": ("a",)}, {})
    assert len(nostage) == 1 and "no LayoutSpec schema" in nostage[0].message


def test_classify_resume_matrix():
    key = types.SimpleNamespace(kind="key")
    rkey = types.SimpleNamespace(kind="replicated_key")
    vert = types.SimpleNamespace(kind="vertex")
    cls, v = classify_resume("s", 0, {"zeta": vert})
    assert cls.startswith("bit-exact") and not v
    cls, v = classify_resume("s", 3, {"key": rkey, "zeta": vert})
    assert cls == "bit-exact (replicated key)" and not v
    cls, v = classify_resume("s", 3, {"key": key, "zeta": vert})
    assert cls.startswith("statistical") and not v
    cls, v = classify_resume("s", 3, {"zeta": vert})
    assert cls == "unresumable" and len(v) == 1


# ---------------------------------------------------------------------------
# the auditor itself (forced 8-device subprocesses, like the CI audit job)
# ---------------------------------------------------------------------------

def test_auditor_catches_violations():
    """Negative control: an undeclared ppermute and a tampered declared
    entry width must both be flagged."""
    out = run_forced_devices("""
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.analysis.congest import audit_program
from repro.core.accounting import StageProgram
from repro.core.distributed import AXIS, audit_spec
from repro.core.routing import shard_map
from repro.graphs import erdos_renyi

mesh = Mesh(np.array(jax.devices()), (AXIS,))
shards = int(mesh.devices.size)

def body(x):
    perm = [(s, (s + 1) % shards) for s in range(shards)]
    return jax.lax.ppermute(x, AXIS, perm)

f = jax.jit(shard_map(body, mesh, P(AXIS), P(AXIS)))
prog = StageProgram(stage="toy", program="perm", fn=f,
                    example_args=(jax.ShapeDtypeStruct((shards, 4),
                                                       jnp.int32),))
_, _, vs = audit_program(prog, "toy")
unexpected = any(v.kind == "budget/unexpected-collective" for v in vs)

spec = audit_spec(erdos_renyi(96, 5.0, seed=1), mesh)
p0 = spec.programs[0]
bad = dataclasses.replace(p0, sites=(dataclasses.replace(
    p0.sites[0], entry_nbytes=8),))
_, _, vs2 = audit_program(bad, "walks")
payload = any(v.kind == "budget/payload" for v in vs2)
print(json.dumps(dict(unexpected=unexpected, payload=payload)))
""", devices=8)
    assert out["unexpected"] and out["payload"]


def test_full_audit_all_engines_clean():
    """The PR's acceptance gate: all five engines, zero violations, exact
    static-vs-telemetry byte agreement, W-free budgets, and the expected
    elastic-resume classifications."""
    out = run_forced_devices("""
import json
from repro.analysis.congest import audit_all_engines
rep = audit_all_engines()
eng = rep["engines"]
print(json.dumps(dict(
    ok=rep["ok"], violations=rep["violations_total"],
    engines=sorted(eng),
    counts=eng["counts"]["resume"]["counts"],
    p1=eng["improved"]["resume"]["phase1"],
    p2=eng["improved"]["resume"]["phase2"],
    p3=eng["improved"]["resume"]["phase3"],
    d2=eng["directed"]["resume"]["phase2"],
    walks=eng["walks"]["resume"]["walks"],
    ppr=eng["ppr"]["resume"]["serve"],
    w=[eng[k]["w_independent"] for k in sorted(eng)],
    tele=[eng[k]["telemetry"]["ok"] for k in sorted(eng)])))
""", devices=8)
    assert out["ok"], out
    assert out["violations"] == 0
    assert out["engines"] == ["counts", "directed", "improved", "ppr",
                              "walks"]
    assert out["counts"] == "bit-exact (replicated key)"
    assert out["p2"] == out["p3"] == out["d2"] == "bit-exact (RNG-free)"
    assert out["p1"].startswith("statistical")
    assert out["walks"].startswith("statistical")
    assert out["ppr"].startswith("statistical")
    assert all(out["w"]) and all(out["tele"])
