"""Unit coverage for the HLO collective parser + roofline smoke.

`collective_bytes` used to double count async collectives twice over: the
`-start` op returns a `(operands..., results...)` tuple (both halves were
summed) and the `-done` op returns the result again (skipped only by a
substring match on the whole line, which misfired on operand names
containing "-done"). These tests pin the structural fix.
"""
import json

from repro.analysis.hlo import collective_bytes, count_ops
from repro.analysis.roofline import build_roofline

SYNC_HLO = """\
HloModule m
ENTRY %main {
  %x = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), replica_groups={}
  ROOT %a2a = s32[8,24]{1,0} all-to-all(s32[8,24]{1,0} %y), dimensions={0}
}
"""

ASYNC_HLO = """\
HloModule m
ENTRY %main {
  %p = f32[4,8]{1,0} parameter(0)
  %ag-start = (f32[4,8]{1,0}, f32[32,8]{1,0}) all-gather-start(f32[4,8]{1,0} %p), dimensions={0}
  %ag-done = f32[32,8]{1,0} all-gather-done((f32[4,8]{1,0}, f32[32,8]{1,0}) %ag-start)
  %cp-start = (u32[2]{0}, u32[2]{0}) collective-permute-start(u32[2]{0} %q)
  %cp-done = u32[2]{0} collective-permute-done((u32[2]{0}, u32[2]{0}) %cp-start)
}
"""


def test_sync_collectives_and_root():
    b = collective_bytes(SYNC_HLO)
    assert b["all-reduce"] == 128 * 4
    # ROOT-prefixed ops must be parsed too
    assert b["all-to-all"] == 8 * 24 * 4


def test_async_pair_counted_once_result_half_only():
    b = collective_bytes(ASYNC_HLO)
    # start tuple = (operand f32[4,8], result f32[32,8]): only the result
    # half is payload, and the -done op must not add anything
    assert b["all-gather"] == 32 * 8 * 4
    assert b["collective-permute"] == 2 * 4


def test_done_detection_is_structural_not_substring():
    # an *operand* named like a done op must not suppress the line
    hlo = "  %x = f32[4]{0} all-reduce(f32[4]{0} %ag-done.1)\n"
    assert collective_bytes(hlo) == {"all-reduce": 16}


def test_count_ops_skips_done_only():
    counts = count_ops(SYNC_HLO + ASYNC_HLO)
    assert counts == {"all-reduce": 1, "all-to-all": 1, "all-gather": 1,
                      "collective-permute": 1}


def test_tuple_shape_sum_without_async_suffix():
    # a plain (non-start) tuple result sums every element
    hlo = "  %t = (f32[2]{0}, s32[3]{0}) all-to-all(f32[2]{0} %a, s32[3]{0} %b)\n"
    assert collective_bytes(hlo) == {"all-to-all": 2 * 4 + 3 * 4}


def test_roofline_smoke():
    cost = {"flops": 1.0e12, "bytes accessed": 2.0e9}
    mem = {"argument_size_in_bytes": 1 << 20, "temp_size_in_bytes": 1 << 18,
           "output_size_in_bytes": 1 << 16}
    r = build_roofline("v5e", "tiny", "dp8", 8, cost, mem, SYNC_HLO,
                       model_flops=6.0e12)
    assert r.coll_breakdown["all-reduce"] == 512
    assert r.coll_bytes == 512 + 768
    assert r.coll_ops == {"all-reduce": 1, "all-to-all": 1}
    assert r.bottleneck in ("compute", "memory", "collective")
    assert r.step_time == max(r.t_compute, r.t_memory, r.t_collective) > 0
    assert 0 < r.mfu < 1
    json.dumps(r.to_dict())  # the dashboard artifact must serialize
