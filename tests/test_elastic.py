"""Elastic-runtime coverage: mesh-size-agnostic snapshots and resume on a
resized mesh.

In-process units exercise the schema-driven repartitioner jax-free(ish):
bit-exact P -> P' -> P round trips for every layout kind, canonical walk
packing, the coupon-slot bijection against a freshly built pool layout,
auto-growing walk caps under skew, and the collision-resistant per-shard
key re-derivation. The Supervisor's mismatch detection / relayout routing /
re-anchor save is unit-tested on toy host state.

The engine-level guarantees run in subprocesses (XLA's device count is
process-global): a run killed on an 8-shard mesh resumes on {1, 2, 4}
shards bit-exactly for the count-state engine (counter-based per-vertex
RNG + replicated round key), bit-exactly for the 3-phase engine when the
kill lands in the RNG-free Phase 2, and tolerance-gated for the directed
engine when per-shard key streams must be re-derived. A second forced-16
subprocess covers growing the mesh (8 -> 16). The resident PPR service is
resized mid-traffic without dropping cached or in-flight queries.
"""
import textwrap

import numpy as np
import pytest

from conftest import run_forced_devices

from repro.checkpoint import (LayoutSpec, derive_shard_keys, pack_json,
                              relayout_arrays, relayout_pagerank_state,
                              relayout_staged_flat)
from repro.checkpoint.elastic import _slot_index


# ---------------------------------------------------------------------------
# in-process units: the schema-driven repartitioner
# ---------------------------------------------------------------------------

def _shard_vertex(base: np.ndarray, n: int, shards: int) -> np.ndarray:
    n_loc = -(-n // shards)
    out = np.zeros((n_loc * shards,) + base.shape[1:], dtype=base.dtype)
    out[:n] = base
    return out.reshape((shards, n_loc) + base.shape[1:])


@pytest.mark.parametrize("p_mid", [1, 3, 16])
def test_vertex_roundtrip_bit_exact(p_mid):
    """vertex buffers re-split along the contiguous partition and round-
    trip 8 -> P' -> 8 bit-exactly, including a trailing feature axis."""
    n = 37
    rng = np.random.default_rng(0)
    base = rng.integers(0, 1000, size=(n, 2)).astype(np.int32)
    spec = dict(z=LayoutSpec(kind="vertex", n=n))
    a8 = _shard_vertex(base, n, 8)
    mid = relayout_arrays(dict(z=a8), spec, 8, p_mid)["z"]
    np.testing.assert_array_equal(mid, _shard_vertex(base, n, p_mid))
    back = relayout_arrays(dict(z=mid), spec, p_mid, 8)["z"]
    np.testing.assert_array_equal(back, a8)


def _walk_multiset(pos, qid=None):
    live = pos.reshape(-1) >= 0
    v = pos.reshape(-1)[live].tolist()
    if qid is None:
        return sorted(v)
    return sorted(zip(v, qid.reshape(-1)[live].tolist()))


@pytest.mark.parametrize("p_mid", [1, 3, 16])
def test_walk_roundtrip_canonical_with_aux(p_mid):
    """Walk lanes (+ their aux lane) preserve the walk multiset under any
    re-bucketing, and with a pinned cap the canonical sorted packing makes
    P -> P' -> P bit-exact."""
    n, cap, P = 50, 24, 8
    rng = np.random.default_rng(1)
    pos = np.full((P, cap), -1, np.int32)
    qid = np.zeros((P, cap), np.int32)
    for _ in range(70):     # scattered, duplicated, unsorted live walks
        p, s = rng.integers(P), rng.integers(cap)
        pos[p, s] = rng.integers(n)
        qid[p, s] = rng.integers(4)
    specs = dict(pos=LayoutSpec(kind="walk", n=n, cap=cap, fill=-1,
                                aux=("qid",)),
                 qid=LayoutSpec(kind="walk_aux", fill=0))
    mid = relayout_arrays(dict(pos=pos, qid=qid), specs, P, p_mid)
    assert _walk_multiset(mid["pos"], mid["qid"]) == \
        _walk_multiset(pos, qid)
    # canonical: re-laying-out an already-canonical layout is the identity
    again = relayout_arrays(mid, specs, p_mid, p_mid)
    np.testing.assert_array_equal(again["pos"], mid["pos"])
    np.testing.assert_array_equal(again["qid"], mid["qid"])
    # round trip lands on the CANONICAL 8-shard packing of the original
    back = relayout_arrays(mid, specs, p_mid, P)
    canon = relayout_arrays(dict(pos=pos, qid=qid), specs, P, P)
    np.testing.assert_array_equal(back["pos"], canon["pos"])
    np.testing.assert_array_equal(back["qid"], canon["qid"])


def test_walk_cap_autogrows_under_skew():
    """Every walk on one vertex: the declared cap cannot hold shard 0's
    bucket, so relayout grows it instead of failing the resume."""
    n = 64
    host = dict(
        pos=np.zeros((2, 32), np.int32),          # 64 walks, all at vertex 0
        zeta=np.zeros((2, 32), np.int32),
        key=np.arange(4, dtype=np.uint32).reshape(2, 2),
        round=np.int32(3), dropped=np.int32(0), waited=np.int32(0))
    out = relayout_pagerank_state(host, n, 8, cap=4)
    assert out["pos"].shape[0] == 8
    assert out["pos"].shape[1] >= 64          # grew past the declared 4
    assert _walk_multiset(out["pos"]) == [0] * 64
    assert out["zeta"].shape == (8, 8)
    assert out["key"].shape == (8, 2)


def test_slot_bijection_matches_fresh_pool_layout():
    """A coupon-slot buffer re-homed 8 -> 3 is bit-identical to the layout
    a fresh 3-shard engine would build, and round-trips bit-exactly."""
    n = 29
    rng = np.random.default_rng(2)
    pool = rng.integers(0, 5, size=n).astype(np.int64)
    total = int(pool.sum())

    def build(shards):
        idx, S = _slot_index(pool, n, shards)
        buf = np.full(shards * S, -1, np.int64)
        buf[idx] = np.arange(total)     # coupon (v, j), vertex-major
        return buf.reshape(shards, S)

    spec = dict(b=LayoutSpec(kind="slot", n=n, pool=pool, fill=-1))
    b8 = build(8)
    got3 = relayout_arrays(dict(b=b8), spec, 8, 3)["b"]
    np.testing.assert_array_equal(got3, build(3))
    back = relayout_arrays(dict(b=got3), spec, 3, 8)["b"]
    np.testing.assert_array_equal(back, b8)
    # a buffer that does not match the claimed old layout is an error
    with pytest.raises(ValueError, match="does not match"):
        relayout_arrays(dict(b=b8), spec, 4, 3)


def test_derive_shard_keys_separates_permuted_layouts():
    """Row-permuted old key arrays must derive DIFFERENT new streams (the
    old XOR-reduce aliased them), and the derivation is deterministic."""
    a = np.arange(16, dtype=np.uint32).reshape(8, 2)
    b = a[::-1].copy()
    # XOR cannot tell these apart — the hash-based derivation must
    assert np.array_equal(np.bitwise_xor.reduce(a.reshape(-1)),
                          np.bitwise_xor.reduce(b.reshape(-1)))
    ka, kb = derive_shard_keys(a, 4), derive_shard_keys(b, 4)
    assert ka.shape == (4, 2)
    assert not np.array_equal(ka, kb)
    np.testing.assert_array_equal(ka, derive_shard_keys(a, 4))
    # distinct shards get distinct keys
    assert len({tuple(row) for row in ka.tolist()}) == 4


def test_relayout_schema_errors():
    arr = np.zeros((2, 4), np.int32)
    with pytest.raises(ValueError, match="no layout schema"):
        relayout_arrays(dict(x=arr), {}, 2, 4)
    with pytest.raises(ValueError, match="unknown layout kind"):
        relayout_arrays(dict(x=arr), dict(x=LayoutSpec(kind="bogus")), 2, 4)
    flat = dict(stage=pack_json("phase9"), host=pack_json({}))
    with pytest.raises(ValueError, match="no layout schema declared"):
        relayout_staged_flat(flat, 2, 4, dict(phase1={}))


def test_relayout_staged_flat_uses_stage_schema():
    """The flat snapshot's stage tag selects the spec map; non-array leaves
    (stage, host accumulators) pass through untouched."""
    n = 6
    base = np.arange(n, dtype=np.int32)
    flat = {"stage": pack_json("count"),
            "host": pack_json(dict(rounds=7)),
            "arrays/z": _shard_vertex(base, n, 8)}
    layouts = dict(count=dict(z=LayoutSpec(kind="vertex", n=n)))
    out = relayout_staged_flat(flat, 8, 2, layouts)
    np.testing.assert_array_equal(out["stage"], flat["stage"])
    np.testing.assert_array_equal(out["host"], flat["host"])
    np.testing.assert_array_equal(out["arrays/z"],
                                  _shard_vertex(base, n, 2))


# ---------------------------------------------------------------------------
# in-process units: Supervisor mismatch detection + re-anchor (jax-free)
# ---------------------------------------------------------------------------

def _toy_supervisor(ck, meta_shards, relayout=None, checkpoint_every=100):
    from repro.runtime import Supervisor

    def step(s):
        s = dict(s, count=int(s["count"]) + 1)
        return s, s["count"] >= 6

    return Supervisor(
        step,
        lambda s: dict(x=np.asarray(s["x"]),
                       count=np.asarray(s["count"])),
        lambda f: dict(x=np.asarray(f["x"]),
                       count=int(np.asarray(f["count"]))),
        ck, checkpoint_every=checkpoint_every,
        meta_fn=lambda: dict(shards=meta_shards), relayout=relayout)


def test_supervisor_shard_mismatch_without_hook_raises(tmp_path):
    from repro.checkpoint import Checkpointer
    ck = Checkpointer(str(tmp_path))
    ck.save(3, dict(x=np.ones(8), count=np.asarray(3)),
            metadata=dict(shards=8))
    sup = _toy_supervisor(ck, meta_shards=4)
    with pytest.raises(ValueError, match="no relayout hook"):
        sup.run(None, resume=True)


def test_supervisor_routes_resume_through_relayout_and_reanchors(tmp_path):
    """Manifest shards != live shards: the restored flat dict goes through
    the relayout hook, and the supervisor immediately re-snapshots the
    NEW-mesh state at the same step so a later crash recovers it."""
    from repro.checkpoint import Checkpointer
    ck = Checkpointer(str(tmp_path))
    ck.save(3, dict(x=np.arange(8, dtype=np.int64), count=np.asarray(3)),
            metadata=dict(shards=8))
    seen = []

    def relayout(flat, old_shards):
        seen.append(old_shards)
        return dict(flat, x=np.asarray(flat["x"]).reshape(4, 2).sum(1))

    sup = _toy_supervisor(ck, meta_shards=4, relayout=relayout)
    res = sup.run(None, resume=True)
    assert seen == [8]
    assert res.restarts == 0 and res.state["count"] == 6
    np.testing.assert_array_equal(res.state["x"], [1, 5, 9, 13])
    # the re-anchor happened at the resumed step, under the NEW mesh size
    flat, manifest = ck.restore()
    assert manifest["metadata"] == dict(shards=4)
    # ...and the final-state snapshot (done-save) is the latest step
    assert manifest["step"] == 6


def test_supervisor_matching_shards_skips_relayout(tmp_path):
    from repro.checkpoint import Checkpointer
    ck = Checkpointer(str(tmp_path))
    ck.save(3, dict(x=np.ones(8), count=np.asarray(3)),
            metadata=dict(shards=8))

    def boom(flat, old):           # must not be consulted on a same-size mesh
        raise AssertionError("relayout called despite matching shards")

    res = _toy_supervisor(ck, meta_shards=8, relayout=boom).run(
        None, resume=True)
    assert res.state["count"] == 6


def test_final_snapshot_written_on_done(tmp_path):
    """A run finishing BETWEEN periodic checkpoints still leaves the
    directory holding its final state (satellite: done-save)."""
    from repro.checkpoint import Checkpointer
    from repro.runtime import Stage, StagedState, StageSchedule, run_staged

    def step(ms):
        ms.host["count"] += 1
        return ms, ms.host["count"] >= 5

    sched = StageSchedule([Stage("s", step)])
    ms = StagedState(stage="s", arrays={}, host=dict(count=0))
    out, restarts, ckpts = run_staged(
        sched, ms, lambda n, a: a, checkpoint_dir=str(tmp_path),
        checkpoint_every=100)
    assert (restarts, ckpts) == (0, 2)      # round-0 anchor + done-save
    from repro.runtime import staged_from_host
    flat, manifest = Checkpointer(str(tmp_path)).restore()
    assert manifest["step"] == 5
    assert staged_from_host(flat, lambda n, a: a).host == dict(count=5)


def test_run_staged_elastic_resume_jax_free(tmp_path):
    """End-to-end through run_staged on toy state: kill at 8 shards,
    resume at 4 — the snapshot re-layouts through the declared schema and
    the manifest re-anchors to the live mesh size."""
    from repro.checkpoint import Checkpointer
    from repro.runtime import (SimulatedFailure, Stage, StagedState,
                               StageSchedule, run_staged)

    n = 6
    base = np.arange(n, dtype=np.int32)
    layouts = dict(s=dict(x=LayoutSpec(kind="vertex", n=n)))

    def step(ms):
        ms.host["count"] += 1
        return ms, ms.host["count"] >= 4

    sched = StageSchedule([Stage("s", step)])
    d = str(tmp_path)
    st8 = StagedState(stage="s", arrays=dict(x=_shard_vertex(base, n, 8)),
                      host=dict(count=0), layouts=layouts, shards=8)
    with pytest.raises(SimulatedFailure):
        run_staged(sched, st8, lambda name, a: a, checkpoint_dir=d,
                   fail_at=[2], checkpoint_every=2, max_restarts=0)
    st4 = StagedState(stage="s", arrays=dict(x=_shard_vertex(base, n, 4)),
                      host=dict(count=0), layouts=layouts, shards=4)
    out, restarts, _ = run_staged(sched, st4, lambda name, a: a,
                                  checkpoint_dir=d, resume=True,
                                  checkpoint_every=100)
    assert restarts == 0 and out.host["count"] == 4
    np.testing.assert_array_equal(out.arrays["x"],
                                  _shard_vertex(base, n, 4))
    assert Checkpointer(d).restore()[1]["metadata"] == dict(shards=4)


# ---------------------------------------------------------------------------
# engine-level elastic resume (subprocess: device count is process-global)
# ---------------------------------------------------------------------------

ELASTIC_CODE = textwrap.dedent("""
    import json, shutil, tempfile
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import l1_error, normalized, power_iteration, topk_overlap
    from repro.core.distributed import AXIS
    from repro.core.distributed_counts import distributed_pagerank_counts
    from repro.core.distributed_directed import distributed_directed_pagerank
    from repro.core.distributed_improved import distributed_improved_pagerank
    from repro.graphs import directed_web, erdos_renyi
    from repro.runtime import SimulatedFailure

    devs = jax.devices()
    def submesh(p):
        return Mesh(np.array(devs[:p]), (AXIS,))

    def flat_zeta(r, n):
        return np.asarray(r.zeta).reshape(-1)[:n]

    def kill(engine, g, K, key, d, fail_at, **kw):
        died = False
        try:
            engine(g, 0.25, K, key, checkpoint_dir=d, fail_at=fail_at,
                   checkpoint_every=2, max_restarts=0, **kw)
        except SimulatedFailure:
            died = True
        return died

    out = {}

    # counts: replicated round key + counter-based per-vertex draws make
    # the trajectory a pure function of (seed, graph) — resume on ANY mesh
    # size must be bit-exact
    g = erdos_renyi(64, 5.0, seed=1)
    key = jax.random.PRNGKey(2)
    ref = distributed_pagerank_counts(g, 0.25, 40, key)
    d = tempfile.mkdtemp(prefix="elastic_counts_")
    died = kill(distributed_pagerank_counts, g, 40, key, d, [3])
    res = dict(died=died, targets={})
    for p in (1, 2, 4):
        dp = d + f"_p{p}"
        shutil.copytree(d, dp)          # pristine kill dir per target
        r = distributed_pagerank_counts(g, 0.25, 40, key, mesh=submesh(p),
                                        checkpoint_dir=dp, resume=True,
                                        checkpoint_every=2)
        res["targets"][str(p)] = dict(
            shards=r.shards, restarts=r.restarts,
            rounds_equal=r.rounds == ref.rounds,
            zeta_equal=bool(np.array_equal(flat_zeta(ref, g.n),
                                           flat_zeta(r, g.n))),
            pi_equal=bool(np.array_equal(np.asarray(ref.pi),
                                         np.asarray(r.pi))))
    out["counts"] = res

    # improved: eta_safety=8.0 drives tail_walks to 0, so the run past
    # Phase 1 is RNG-free — a mid-Phase-2 kill resumed on a shrunk mesh
    # must reproduce the unfailed run bit-exactly
    g2 = erdos_renyi(96, 5.0, seed=1)
    ref2 = distributed_improved_pagerank(g2, 0.25, 40, jax.random.PRNGKey(0),
                                         eta_safety=8.0)
    mid_p2 = (ref2.phase1_rounds + ref2.report_rounds
              + max(ref2.phase2_rounds // 2, 1))
    d2 = tempfile.mkdtemp(prefix="elastic_improved_")
    died2 = kill(distributed_improved_pagerank, g2, 40, jax.random.PRNGKey(0),
                 d2, [mid_p2], eta_safety=8.0)
    r2 = distributed_improved_pagerank(g2, 0.25, 40, jax.random.PRNGKey(0),
                                       mesh=submesh(4), checkpoint_dir=d2,
                                       resume=True, checkpoint_every=2,
                                       eta_safety=8.0)
    out["improved"] = dict(
        died=died2, fail_at=mid_p2, tail_walks=ref2.tail_walks,
        shards=r2.shards, restarts=r2.restarts, dropped=r2.dropped,
        zeta_equal=bool(np.array_equal(flat_zeta(ref2, g2.n),
                                       flat_zeta(r2, g2.n))),
        pi_equal=bool(np.array_equal(np.asarray(ref2.pi),
                                     np.asarray(r2.pi))))

    # directed: kill inside keyed Phase 1 — the resume re-derives fresh
    # per-shard key streams, so exactness is statistical: gate on the same
    # L1/top-10 conformance thresholds the launch --check uses
    g3 = directed_web(64, 5.0, seed=3)
    d3 = tempfile.mkdtemp(prefix="elastic_directed_")
    died3 = kill(distributed_directed_pagerank, g3, 20, jax.random.PRNGKey(3),
                 d3, [1])
    r3 = distributed_directed_pagerank(g3, 0.25, 20, jax.random.PRNGKey(3),
                                       mesh=submesh(4), checkpoint_dir=d3,
                                       resume=True, checkpoint_every=2)
    pi_ref, _, _ = power_iteration(g3, 0.25)
    pi3 = np.asarray(r3.pi, dtype=np.float64)
    out["directed"] = dict(
        died=died3, shards=r3.shards, restarts=r3.restarts,
        dropped=r3.dropped,
        l1=float(l1_error(pi3 / pi3.sum(), pi_ref)),
        topk=float(topk_overlap(pi3, np.asarray(pi_ref))))

    print(json.dumps(out))
""")


SERVE_CODE = textwrap.dedent("""
    import json
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import l1_error, normalized, topk_overlap
    from repro.core.distributed import AXIS
    from repro.core.personalized import exact_ppr
    from repro.graphs import erdos_renyi
    from repro.serve.ppr_service import PPRService

    devs = jax.devices()
    def submesh(p):
        return Mesh(np.array(devs[:p]), (AXIS,))

    g = erdos_renyi(96, 5.0, seed=1)
    svc = PPRService(g, 0.25, slots=2, walks_per_query=4096,
                     mesh=submesh(4))
    r1 = svc.submit([3], now=0.0)
    r2 = svc.submit([10, 17], now=0.0)
    for _ in range(2):                  # both queries mid-flight
        svc.step(now=0.0)
    svc.resize(mesh=submesh(2))         # shrink the mesh under them
    r3 = svc.submit([5], now=0.0)       # admitted post-resize
    svc.drain(now=0.0)

    qs = dict(q1=(r1, [3]), q2=(r2, [10, 17]), q3=(r3, [5]))
    acc = {}
    for name, (req, sources) in qs.items():
        ref = exact_ppr(g, 0.25, sources)
        acc[name] = dict(
            done=req.done,
            l1=float(l1_error(normalized(req.result), normalized(ref))),
            topk=float(topk_overlap(req.result, ref)))
    # a post-resize cache hit serves the STORED pre-resize vector
    hit = svc.submit([3], now=0.0)
    out = dict(
        acc=acc, dropped=svc.stats.dropped_walks,
        admit_dropped=svc.stats.admit_dropped,
        completed=svc.stats.completed,
        cache_hit=bool(hit.cached),
        cache_bitexact=bool(np.array_equal(hit.result, r1.result)))
    print(json.dumps(out))
""")


GROW_CODE = textwrap.dedent("""
    import json, tempfile
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core.distributed import AXIS
    from repro.core.distributed_counts import distributed_pagerank_counts
    from repro.graphs import erdos_renyi
    from repro.runtime import SimulatedFailure

    devs = jax.devices()
    g = erdos_renyi(64, 5.0, seed=1)
    key = jax.random.PRNGKey(2)
    mesh8 = Mesh(np.array(devs[:8]), (AXIS,))
    ref = distributed_pagerank_counts(g, 0.25, 40, key, mesh=mesh8)
    d = tempfile.mkdtemp(prefix="elastic_grow_")
    died = False
    try:
        distributed_pagerank_counts(g, 0.25, 40, key, mesh=mesh8,
                                    checkpoint_dir=d, fail_at=[3],
                                    checkpoint_every=2, max_restarts=0)
    except SimulatedFailure:
        died = True
    mesh16 = Mesh(np.array(devs), (AXIS,))
    r = distributed_pagerank_counts(g, 0.25, 40, key, mesh=mesh16,
                                    checkpoint_dir=d, resume=True,
                                    checkpoint_every=2)
    fz = lambda x: np.asarray(x.zeta).reshape(-1)[:g.n]
    print(json.dumps(dict(
        died=died, shards=r.shards, restarts=r.restarts,
        rounds_equal=r.rounds == ref.rounds,
        zeta_equal=bool(np.array_equal(fz(ref), fz(r))))))
""")


@pytest.fixture(scope="module")
def payload():
    # hard-requires an 8-device mesh (shrink targets 1/2/4), so the count
    # is forced rather than REPRO_TEST_DEVICES-steered
    return run_forced_devices(ELASTIC_CODE, devices=8)


@pytest.fixture(scope="module")
def serve_payload():
    return run_forced_devices(SERVE_CODE, devices=8)


@pytest.mark.parametrize("target", ["1", "2", "4"])
def test_counts_elastic_resume_bit_exact(target, payload):
    """Kill at 8 shards, resume at P' — zeta/pi bit-identical to the
    unfailed 8-shard run, with no in-process restarts."""
    r = payload["counts"]
    assert r["died"], r
    t = r["targets"][target]
    assert t["shards"] == int(target), t
    assert t["restarts"] == 0, t
    assert t["zeta_equal"] and t["pi_equal"] and t["rounds_equal"], t


def test_improved_midphase2_elastic_resume_bit_exact(payload):
    """Phase 2 is RNG-free (and tail empty at eta_safety=8): a mid-Phase-2
    kill resumed on 4 shards reproduces the 8-shard run bit for bit."""
    r = payload["improved"]
    assert r["died"], r
    assert r["tail_walks"] == 0, r       # precondition for exactness
    assert r["shards"] == 4 and r["restarts"] == 0, r
    assert r["zeta_equal"] and r["pi_equal"], r
    assert r["dropped"] == 0, r


def test_directed_keyed_elastic_resume_conformance(payload):
    """A kill in keyed Phase 1 forces key re-derivation: the resumed run is
    a fresh trajectory, gated by the launch --check tolerances."""
    r = payload["directed"]
    assert r["died"], r
    assert r["shards"] == 4 and r["restarts"] == 0, r
    assert r["dropped"] == 0, r
    assert r["l1"] < 0.15 and r["topk"] >= 0.6, r


def test_counts_elastic_resume_grows_mesh():
    """8 -> 16 shards (growing needs its own forced-16 process)."""
    r = run_forced_devices(GROW_CODE, devices=16)
    assert r["died"], r
    assert r["shards"] == 16 and r["restarts"] == 0, r
    assert r["zeta_equal"] and r["rounds_equal"], r


def test_ppr_service_resize_mid_traffic(serve_payload):
    """Shrinking the resident engine's mesh mid-flight drops nothing: in-
    flight queries finish on the new mesh within tolerance, and the cache
    keeps serving pre-resize vectors bit-identically."""
    r = serve_payload
    assert r["dropped"] == 0 and r["admit_dropped"] == 0, r
    assert r["completed"] == 3, r
    for name, a in r["acc"].items():
        assert a["done"], (name, a)
        assert a["l1"] < 0.15 and a["topk"] >= 0.6, (name, a)
    assert r["cache_hit"] and r["cache_bitexact"], r
