"""Per-arch reduced-config smoke: forward + one train step on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, input_specs, reduced_config
from repro.models import get_model
from repro.train import AdamWConfig, init_state, make_train_step


def _batch(cfg, B=2, T=16):
    b = dict(tokens=jnp.ones((B, T), jnp.int32),
             labels=jnp.ones((B, T), jnp.int32))
    if cfg.family == "audio":
        b["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    if cfg.family == "vlm":
        b["img_embeds"] = jnp.zeros((B, cfg.num_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch, key):
    cfg = reduced_config(arch)
    model = get_model(cfg)
    params, axes = model.init_params(cfg, key)
    # params/axes trees line up
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(
                axes, is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x)))
    batch = _batch(cfg)
    loss, metrics = model.loss_fn(params, batch, cfg, q_chunk=8)
    assert jnp.isfinite(loss), arch
    # one optimizer step on the same batch must reduce the loss
    adam = AdamWConfig(lr=1e-2)
    opt = init_state(params, adam)
    step = make_train_step(cfg, model, adam, loss_kwargs=dict(q_chunk=8))
    p2, opt, m = step(params, opt, batch)
    loss2, _ = model.loss_fn(p2, batch, cfg, q_chunk=8)
    assert jnp.isfinite(m["grad_norm"])
    assert float(loss2) < float(loss), (arch, float(loss), float(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_microbatched_grads_match_full(arch, key):
    """Gradient accumulation over microbatches == single-batch gradients."""
    cfg = reduced_config(arch)
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    batch = _batch(cfg, B=4, T=8)

    def loss_of(p, b):
        return model.loss_fn(p, b, cfg, q_chunk=8)[0]

    g_full = jax.grad(loss_of)(params, batch)
    halves = jax.tree_util.tree_map(
        lambda x: x.reshape((2, 2) + x.shape[1:]), batch)
    g_half0 = jax.grad(loss_of)(params, jax.tree_util.tree_map(
        lambda x: x[0], halves))
    g_half1 = jax.grad(loss_of)(params, jax.tree_util.tree_map(
        lambda x: x[1], halves))
    g_acc = jax.tree_util.tree_map(lambda a, b: (a + b) / 2, g_half0, g_half1)
    flat_a = jnp.concatenate([x.astype(jnp.float32).reshape(-1)
                              for x in jax.tree_util.tree_leaves(g_full)])
    flat_b = jnp.concatenate([x.astype(jnp.float32).reshape(-1)
                              for x in jax.tree_util.tree_leaves(g_acc)])
    # bf16 params: accumulate order differs; require close, not equal
    denom = jnp.maximum(jnp.abs(flat_a).max(), 1e-6)
    assert float(jnp.abs(flat_a - flat_b).max() / denom) < 0.08


def test_param_counts_match_configs():
    """Analytic param_count ~ actual leaf count on reduced configs (<12%)."""
    for arch in ARCHS:
        cfg = reduced_config(arch)
        if cfg.family in ("hybrid", "audio"):
            continue  # analytic formula covers LM stacks only
        model = get_model(cfg)
        params, _ = model.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.12, (arch, actual, est)


def test_full_config_param_counts():
    """Sanity-check the headline parameter counts of the full configs."""
    expect = {
        "deepseek-v2-236b": (200e9, 280e9),
        "dbrx-132b": (115e9, 150e9),
        "qwen2-7b": (6e9, 9e9),
        "nemotron-4-340b": (300e9, 380e9),
        "qwen3-32b": (28e9, 38e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_long_500k_applicability():
    from repro.configs import shape_applicable
    runs = {a for a in ARCHS
            if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert runs == {"h2o-danube-3-4b", "mamba2-1.3b", "recurrentgemma-9b"}


def test_padded_heads_exact(key):
    """TP head padding (28->32 style) is mathematically exact: identical
    loss and exactly-zero gradients on the padded slots."""
    import dataclasses
    import jax.tree_util as tu
    cfg = dataclasses.replace(reduced_config("qwen2-7b"), num_heads=3,
                              num_kv_heads=1, head_dim=16)
    cfgp = dataclasses.replace(cfg, pad_q_heads_to=4)
    model = get_model(cfgp)
    params, _ = model.init_params(cfgp, key)
    batch = dict(tokens=jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
                 labels=jax.random.randint(key, (2, 16), 0, cfg.vocab_size))
    g = jax.grad(lambda p: model.loss_fn(p, batch, cfgp, q_chunk=8)[0])(params)
    for path, leaf in tu.tree_flatten_with_path(g)[0]:
        sp = str(path)
        if sp.endswith("'wq']") and leaf.ndim == 4:
            assert float(jnp.abs(leaf[:, :, 3:]).max()) == 0.0
        if sp.endswith("'wo']") and leaf.ndim == 4:
            assert float(jnp.abs(leaf[:, 3:]).max()) == 0.0
