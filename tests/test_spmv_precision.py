"""Integer-count precision guard for the segment_spmv kernel wrapper.

The Pallas kernel accumulates in float32, which represents integers
exactly only up to 2**24. Engines declare the largest reachable count via
`count_bound`; when the bound exceeds the f32 exact range the wrapper
must widen to an exact integer reduction instead of silently truncating.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.routing import _seg_reduce
from repro.kernels.segment_spmv.ops import F32_EXACT_MAX, segment_spmv


def _exact_ref(values, dst, num_segments):
    out = np.zeros(num_segments, dtype=np.int64)
    for v, d in zip(np.asarray(values), np.asarray(dst)):
        if 0 <= d < num_segments:
            out[d] += int(v)
    return out


def test_f32_collision_is_real():
    # the failure mode being guarded: 2**24 + 1 is not representable
    assert np.float32(2 ** 24) + np.float32(1) == np.float32(2 ** 24)


def test_kernel_path_exact_below_bound():
    rng = np.random.default_rng(0)
    values = jnp.asarray(rng.integers(0, 1000, size=256), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(-1, 17, size=256), dtype=jnp.int32)
    out = segment_spmv(values, dst, 16, count_bound=1 << 20)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), _exact_ref(values, dst, 16))


def test_widens_to_exact_past_f32_range():
    # two entries summing to 2**24 + 1: the f32 path would return 2**24
    values = jnp.asarray([F32_EXACT_MAX, 1, 7], dtype=jnp.int32)
    dst = jnp.asarray([0, 0, 1], dtype=jnp.int32)
    out = segment_spmv(values, dst, 2, count_bound=F32_EXACT_MAX + 1)
    assert int(out[0]) == F32_EXACT_MAX + 1
    assert int(out[1]) == 7


def test_widened_path_keeps_drop_semantics():
    # invalid destinations (negative / >= num_segments) must still drop
    values = jnp.asarray([F32_EXACT_MAX, 5, 9], dtype=jnp.int32)
    dst = jnp.asarray([0, -1, 2], dtype=jnp.int32)
    out = segment_spmv(values, dst, 2, count_bound=F32_EXACT_MAX + 1)
    np.testing.assert_array_equal(np.asarray(out), [F32_EXACT_MAX, 0])


def test_seg_reduce_pallas_threads_count_bound():
    # the routing layer's reduction entry point: with use_pallas=True and
    # a declared bound past 2**24 the exact widening must kick in
    values = jnp.asarray([F32_EXACT_MAX, 1], dtype=jnp.int32)
    seg = jnp.asarray([3, 3], dtype=jnp.int32)
    out = _seg_reduce(values, seg, 8, True, count_bound=F32_EXACT_MAX + 2)
    assert int(out[3]) == F32_EXACT_MAX + 1
    # and below the bound both paths agree with the exact reference
    small = jnp.asarray([10, 20, 30], dtype=jnp.int32)
    seg2 = jnp.asarray([1, 1, 5], dtype=jnp.int32)
    for use_pallas in (False, True):
        got = _seg_reduce(small, seg2, 8, use_pallas, count_bound=60)
        np.testing.assert_array_equal(np.asarray(got),
                                      _exact_ref(small, seg2, 8))
