"""Section-5 directed edge cases: dangling nodes, SCC structure, and the
uniform (LOCAL-model) coupon budgets — single-device and sharded."""
import math
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (directed_local_pagerank, l1_error, normalized,
                        power_iteration)
from repro.core.graph import from_edges
from repro.core.improved_pagerank import coupon_pool_sizes
from repro.graphs import directed_web

from conftest import run_forced_devices

EPS = 0.25

# exec-able source (conftest SMALL_GRAPHS_SRC pattern) so the in-process
# tests and the distributed subprocess build the IDENTICAL graph
DANGLING_WEB_SRC = """
import numpy as np
from repro.core.graph import from_edges

def dangling_web(n=32, n_sinks=3, seed=0):
    '''Directed graph where the last `n_sinks` vertices have no out-edges
    (walks arriving there take an immediate reset).'''
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    for v in range(n - n_sinks):
        for u in rng.choice(n, size=3, replace=False):
            if u != v:
                src_l.append(v)
                dst_l.append(int(u))
    g = from_edges(np.array(src_l), np.array(dst_l), n, undirected=False)
    deg = np.asarray(g.out_deg)
    assert (deg[-n_sinks:] == 0).all() and (deg[:-n_sinks] > 0).all()
    return g
"""
_ns = {}
exec(DANGLING_WEB_SRC, _ns)
_dangling_web = _ns["dangling_web"]


# ---------------------------------------------------------------------------
# uniform (Section-5) coupon budgets
# ---------------------------------------------------------------------------

def test_uniform_pool_sizes_are_uniform():
    g = directed_web(64, 5.0, seed=1)
    eta, pool = coupon_pool_sizes(g, 0.2, 100, 5, degree_proportional=False,
                                  ell=23)
    assert (pool == pool[0]).all()            # same budget for every node
    assert pool.shape == (64,)
    assert eta == math.ceil(2.0 * 100 * 23 / 5)
    assert pool[0] == eta * math.ceil(math.log(64))


def test_uniform_pool_explicit_eta_and_scaling():
    g = directed_web(64, 5.0, seed=1)
    _, pool7 = coupon_pool_sizes(g, 0.2, 100, 5, eta=7,
                                 degree_proportional=False)
    assert (pool7 == 7 * math.ceil(math.log(64))).all()
    eta1, _ = coupon_pool_sizes(g, 0.2, 100, 5, degree_proportional=False,
                                ell=23)
    eta2, _ = coupon_pool_sizes(g, 0.2, 200, 5, degree_proportional=False,
                                ell=23)
    assert eta2 == 2 * eta1                   # budget scales with walk load
    with pytest.raises(ValueError):           # needs ell unless eta given
        coupon_pool_sizes(g, 0.2, 100, 5, degree_proportional=False)


def test_degree_proportional_pools_unchanged():
    """The shared helper must keep the Lemma-2 behavior for Algorithm 2."""
    g = directed_web(64, 5.0, seed=1)
    eta, pool = coupon_pool_sizes(g, 0.2, 100, 3)
    deg = np.asarray(g.out_deg).astype(np.int64)
    np.testing.assert_array_equal(pool, np.maximum(deg * eta, 1))


# ---------------------------------------------------------------------------
# dangling nodes: immediate reset, consistent with the power-iteration
# convention (dangling row = uniform teleport)
# ---------------------------------------------------------------------------

def test_dangling_nodes_single_device():
    g = _dangling_web()
    pi_ref, _, _ = power_iteration(g, EPS)
    res = directed_local_pagerank(g, EPS, walks_per_node=200,
                                  key=jax.random.PRNGKey(2))
    assert l1_error(normalized(res.pi), pi_ref) < 0.15
    # early resets at sinks: strictly fewer visits than the no-dangling
    # expectation nK/eps, but the estimator must stay a distribution
    assert int(res.zeta.sum()) < g.n * 200 / EPS
    assert 0.0 < float(res.pi.sum()) <= 1.05


# ---------------------------------------------------------------------------
# SCC structure
# ---------------------------------------------------------------------------

def test_single_scc_cycle_is_uniform():
    n = 24
    v = np.arange(n)
    g = from_edges(v, (v + 1) % n, n, undirected=False)
    pi_ref, _, _ = power_iteration(g, EPS)
    res = directed_local_pagerank(g, EPS, walks_per_node=200,
                                  key=jax.random.PRNGKey(3))
    assert l1_error(normalized(res.pi), pi_ref) < 0.15
    np.testing.assert_allclose(np.asarray(res.pi), 1.0 / n, rtol=0.35)


def test_multi_scc_mass_flows_downstream():
    """Two cycles A -> B joined by a one-way bridge: the downstream SCC
    must end up with more stationary mass, and the engine must agree with
    power iteration about it."""
    k = 12
    v = np.arange(k)
    src = np.concatenate([v, k + v, [0]])            # A-cycle, B-cycle,
    dst = np.concatenate([(v + 1) % k, k + (v + 1) % k, [k]])  # bridge A0->B0
    g = from_edges(src, dst, 2 * k, undirected=False)
    pi_ref, _, _ = power_iteration(g, EPS)
    res = directed_local_pagerank(g, EPS, walks_per_node=300,
                                  key=jax.random.PRNGKey(4))
    pi = np.asarray(normalized(res.pi))
    assert l1_error(pi, pi_ref) < 0.15
    assert pi[k:].sum() > pi[:k].sum()               # downstream-heavy
    assert np.asarray(pi_ref)[k:].sum() > np.asarray(pi_ref)[:k].sum()


# ---------------------------------------------------------------------------
# sharded Section-5 engine on a dangling directed graph (subprocess: the
# device count is process-global); honors REPRO_TEST_DEVICES like the
# conformance suite so the 1-device CI leg covers the single-shard path
# ---------------------------------------------------------------------------

def test_distributed_directed_dangling():
    code = textwrap.dedent("""
        import json, jax
        from repro.core import (directed_local_pagerank, l1_error,
                                normalized, power_iteration)
        from repro.core.distributed_directed import (
            distributed_directed_pagerank)
    """) + DANGLING_WEB_SRC + textwrap.dedent("""
        g = dangling_web()
        pi_ref, _, _ = power_iteration(g, 0.25)
        rd = distributed_directed_pagerank(g, 0.25, 60,
                                           jax.random.PRNGKey(5))
        rs = directed_local_pagerank(g, 0.25, walks_per_node=60,
                                     key=jax.random.PRNGKey(6))
        print(json.dumps(dict(
            n=g.n, W=g.n * 60,
            l1=l1_error(normalized(rd.pi), pi_ref),
            l1_cross=l1_error(normalized(rd.pi), normalized(rs.pi)),
            dropped=rd.dropped, dangling=rd.dangling_nodes,
            budget=rd.uniform_budget, created=rd.coupons_created,
            conserved=rd.terminated_by_coupon + rd.tail_walks == g.n * 60,
            zeta=int(rd.zeta.sum()))))
    """)
    r = run_forced_devices(code, timeout=1200)
    assert r["dangling"] == 3                      # telemetry sees the sinks
    assert r["dropped"] == 0
    assert r["conserved"]
    assert r["created"] == r["n"] * r["budget"]    # uniform budgets
    assert r["l1"] < 0.15, r["l1"]
    assert r["l1_cross"] < 0.3, r["l1_cross"]
    # dangling resets shorten walks: visit mass strictly below nK/eps
    assert r["zeta"] < r["W"] / 0.25