"""Multi-device engine tests (subprocess with 8 forced host devices):
distributed == single-device statistically, bit-exact failure recovery,
and a mini production-path dry-run compile on a 2x2 mesh."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_matches_reference():
    r = _run(textwrap.dedent("""
        import json, jax, numpy as np
        from repro.core import power_iteration, l1_error, normalized
        from repro.core.distributed import distributed_pagerank
        from repro.graphs import erdos_renyi
        g = erdos_renyi(200, 6.0, seed=3)
        pi_ref, _, _ = power_iteration(g, 0.2)
        res = distributed_pagerank(g, 0.2, walks_per_node=100,
                                   key=jax.random.PRNGKey(0))
        print(json.dumps(dict(
            shards=res.shards, rounds=res.rounds, dropped=res.dropped,
            l1=l1_error(normalized(res.pi), pi_ref),
            zeta=int(res.zeta.sum()))))
    """))
    assert r["shards"] == 8
    assert r["dropped"] == 0
    assert r["l1"] < 0.12
    assert abs(r["zeta"] - 200 * 100 / 0.2) / (200 * 100 / 0.2) < 0.05


def test_failure_recovery_bit_exact():
    r = _run(textwrap.dedent("""
        import json, tempfile, jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core.distributed import (AXIS, DistState, _make_superstep,
                                            shard_graph, state_to_host,
                                            state_from_host)
        from repro.graphs import erdos_renyi
        from repro.checkpoint import Checkpointer
        from repro.runtime import Supervisor, FailureSchedule
        g = erdos_renyi(64, 5.0, seed=7)
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
        P_ = mesh.devices.size
        sg = shard_graph(g, P_)
        K = 50; W = g.n * K
        cap = 2*W//P_ + P_*64
        pos0 = np.full((P_, cap), -1, np.int32)
        zeta0 = np.zeros((P_, sg.n_loc), np.int32)
        for p in range(P_):
            lo, hi = p*sg.n_loc, min((p+1)*sg.n_loc, g.n)
            locs = np.repeat(np.arange(lo, hi, dtype=np.int32), K)
            pos0[p,:len(locs)] = locs; zeta0[p,:hi-lo] = K
        spec = NamedSharding(mesh, P(AXIS))
        keys = jax.random.split(jax.random.PRNGKey(5), P_)
        def mk():
            return DistState(pos=jax.device_put(jnp.asarray(pos0), spec),
                             zeta=jax.device_put(jnp.asarray(zeta0), spec),
                             key=jax.device_put(keys, spec),
                             round=jnp.int32(0), dropped=jnp.int32(0),
                             waited=jnp.int32(0))
        rp, ci, dg = (jax.device_put(x, spec)
                      for x in (sg.row_ptr, sg.col_idx, sg.out_deg))
        step = _make_superstep(mesh, 0.25, sg.n_loc, P_, W//P_+64, 0)
        def step_fn(s):
            s2, active, _, _ = step(rp, ci, dg, s)
            return s2, int(active) == 0
        s = mk(); done = False
        while not done: s, done = step_fn(s)
        ref = np.asarray(s.zeta)
        with tempfile.TemporaryDirectory() as d:
            sup = Supervisor(step_fn, state_to_host,
                             lambda f: state_from_host(f, mesh),
                             Checkpointer(d), checkpoint_every=5,
                             failure_schedule=FailureSchedule([7, 13]))
            res = sup.run(mk())
        print(json.dumps(dict(
            restarts=res.restarts,
            exact=bool(np.array_equal(ref, np.asarray(res.state.zeta))))))
    """))
    assert r["restarts"] == 2
    assert r["exact"] is True


def test_mini_production_dryrun_compiles():
    """The full dryrun path (rules, shardings, lower, compile, roofline)
    on a reduced config and a 2x2 production-style mesh."""
    r = _run(textwrap.dedent("""
        import json, jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from functools import partial
        from repro.configs import reduced_config
        from repro.models import get_model
        from repro.sharding import ShardingRules, active_rules, default_rules
        from repro.train import AdamWConfig, init_state, make_train_step
        from repro.analysis.hlo import collective_bytes
        cfg = reduced_config("dbrx-132b")
        model = get_model(cfg)
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                    ("data", "model"))
        rules = ShardingRules(mesh, default_rules(False))
        params_sds = jax.eval_shape(
            lambda k: model.init_params(cfg, k)[0], jax.random.PRNGKey(0))
        _, axes = model.init_params(cfg, jax.random.PRNGKey(0))
        p_sh = rules.tree_shardings(params_sds, axes)
        adam = AdamWConfig()
        opt_sds = jax.eval_shape(partial(init_state, cfg=adam), params_sds)
        from repro.train.optimizer import state_axes
        o_sh = rules.tree_shardings(opt_sds, state_axes(axes, False))
        with active_rules(rules):
            step = make_train_step(cfg, model, adam, num_microbatches=2,
                                   loss_kwargs=dict(q_chunk=8))
            batch = dict(tokens=jax.ShapeDtypeStruct((8, 16), jnp.int32),
                         labels=jax.ShapeDtypeStruct((8, 16), jnp.int32))
            b_sh = {k: rules.sharding(("batch", None), v.shape)
                    for k, v in batch.items()}
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch)
            compiled = lowered.compile()
        coll = collective_bytes(compiled.as_text())
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax < 0.5 returns [dict]
            cost = cost[0]
        print(json.dumps(dict(
            ok=True, flops=float(cost.get("flops", 0)),
            has_collectives=bool(coll))))
    """), devices=4)
    assert r["ok"] and r["flops"] > 0
    assert r["has_collectives"]  # DP/TP must produce real collectives
