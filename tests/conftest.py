import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forced_devices(code: str, devices: int | None = None,
                       timeout: int = 1800):
    """Run `code` in a fresh interpreter with a forced host device count
    and parse its last stdout line as JSON.

    XLA's device count is process-global, so every multi-device suite goes
    through here. `devices=None` honors REPRO_TEST_DEVICES (the CI matrix
    leg; default 8); pass an explicit count for suites whose assertions
    hard-require a fixed mesh.
    """
    if devices is None:
        devices = int(os.environ.get("REPRO_TEST_DEVICES", "8"))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# Single source of truth for the shared small-graph fixture set. The
# distributed suites re-build it inside fresh subprocesses (XLA's device
# count is process-global), so it is kept as exec-able source and the
# in-process fixture below is derived from the SAME string — the two can
# not diverge.
SMALL_GRAPHS_SRC = """
from repro.graphs import (barabasi_albert, barabasi_albert_hub,
                          directed_web, erdos_renyi, grid2d, ring)
graphs = dict(ring=ring(64), grid=grid2d(8, 8),
              er=erdos_renyi(96, 5.0, seed=1),
              ba=barabasi_albert(96, 3, seed=2),
              ba_hub=barabasi_albert_hub(96, 3, seed=4),
              dweb=directed_web(96, 5.0, seed=3))
"""


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_graphs():
    ns = {}
    exec(SMALL_GRAPHS_SRC, ns)
    return ns["graphs"]
