import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_graphs():
    from repro.graphs import barabasi_albert, erdos_renyi, grid2d, ring
    return {
        "ring": ring(64),
        "grid": grid2d(8, 8),
        "er": erdos_renyi(96, 5.0, seed=1),
        "ba": barabasi_albert(96, 3, seed=2),
    }
