"""Serving: incremental decode ≡ full-context forward; continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import get_model
from repro.serve import ContinuousBatcher, Request


def _extras(cfg, B, key):
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        extra["img_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return extra


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_full_forward(arch, key):
    cfg = reduced_config(arch)
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    B, T = 2, 24
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    extra = _extras(cfg, B, key)
    ref_logits, _ = model.prefill(params, toks, cfg, q_chunk=8, **extra)
    _, cache = model.prefill(params, toks[:, :T], cfg, q_chunk=8,
                             pad_cache_to=T + 48, **extra)
    dec_logits, _ = model.decode_step(params, cache, toks[:, T:T + 1], cfg)
    a = np.asarray(ref_logits[:, -1], np.float32)
    b = np.asarray(dec_logits[:, -1], np.float32)
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert err < 0.05, (arch, err)


def test_multi_step_decode_consistency(key):
    """Greedy decode token-by-token == teacher-forced full forwards."""
    cfg = reduced_config("qwen3-32b")
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    B, T, n_new = 1, 10, 5
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    _, cache = model.prefill(params, toks, cfg, q_chunk=8,
                             pad_cache_to=T + n_new + 8)
    seq = list(np.asarray(toks[0]))
    # drive from prefill's next-token prediction
    pre_logits, _ = model.prefill(params, toks, cfg, q_chunk=8)
    nxt = int(jnp.argmax(pre_logits[0, -1]))
    for _ in range(n_new):
        seq.append(nxt)
        full_logits, _ = model.prefill(
            params, jnp.asarray([seq], jnp.int32), cfg, q_chunk=8)
        want = int(jnp.argmax(full_logits[0, -1]))
        step_logits, cache = model.decode_step(
            params, cache, jnp.asarray([[nxt]], jnp.int32), cfg)
        got = int(jnp.argmax(step_logits[0, -1]))
        assert got == want
        nxt = got


def test_continuous_batching_matches_isolated(key):
    cfg = reduced_config("h2o-danube-3-4b")  # exercises SWA ring buffers
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    rng = np.random.default_rng(0)

    def greedy_ref(prompt, n_new):
        toks = jnp.asarray(prompt[None, :])
        logits, cache = model.prefill(params, toks, cfg, q_chunk=64,
                                      pad_cache_to=64)
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(n_new - 1):
            logits, cache = model.decode_step(
                params, cache, jnp.asarray([[out[-1]]], jnp.int32), cfg)
            out.append(int(jnp.argmax(logits[0, -1])))
        return out

    prompts = [rng.integers(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in (5, 9, 7)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    stats = ContinuousBatcher(model, params, cfg, slots=2,
                              max_seq=64).run(reqs)
    assert stats.completed == 3
    for r, p in zip(reqs, prompts):
        assert r.generated[:5] == greedy_ref(p, 5), r.rid
