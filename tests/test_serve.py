"""Serving: incremental decode ≡ full-context forward; continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import get_model
from repro.serve import ContinuousBatcher, Request


def _extras(cfg, B, key):
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        extra["img_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return extra


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_full_forward(arch, key):
    cfg = reduced_config(arch)
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    B, T = 2, 24
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    extra = _extras(cfg, B, key)
    ref_logits, _ = model.prefill(params, toks, cfg, q_chunk=8, **extra)
    _, cache = model.prefill(params, toks[:, :T], cfg, q_chunk=8,
                             pad_cache_to=T + 48, **extra)
    dec_logits, _ = model.decode_step(params, cache, toks[:, T:T + 1], cfg)
    a = np.asarray(ref_logits[:, -1], np.float32)
    b = np.asarray(dec_logits[:, -1], np.float32)
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert err < 0.05, (arch, err)


def test_multi_step_decode_consistency(key):
    """Greedy decode token-by-token == teacher-forced full forwards."""
    cfg = reduced_config("qwen3-32b")
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    B, T, n_new = 1, 10, 5
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    _, cache = model.prefill(params, toks, cfg, q_chunk=8,
                             pad_cache_to=T + n_new + 8)
    seq = list(np.asarray(toks[0]))
    # drive from prefill's next-token prediction
    pre_logits, _ = model.prefill(params, toks, cfg, q_chunk=8)
    nxt = int(jnp.argmax(pre_logits[0, -1]))
    for _ in range(n_new):
        seq.append(nxt)
        full_logits, _ = model.prefill(
            params, jnp.asarray([seq], jnp.int32), cfg, q_chunk=8)
        want = int(jnp.argmax(full_logits[0, -1]))
        step_logits, cache = model.decode_step(
            params, cache, jnp.asarray([[nxt]], jnp.int32), cfg)
        got = int(jnp.argmax(step_logits[0, -1]))
        assert got == want
        nxt = got


def test_continuous_batching_matches_isolated(key):
    cfg = reduced_config("h2o-danube-3-4b")  # exercises SWA ring buffers
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    rng = np.random.default_rng(0)

    def greedy_ref(prompt, n_new):
        toks = jnp.asarray(prompt[None, :])
        logits, cache = model.prefill(params, toks, cfg, q_chunk=64,
                                      pad_cache_to=64)
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(n_new - 1):
            logits, cache = model.decode_step(
                params, cache, jnp.asarray([[out[-1]]], jnp.int32), cfg)
            out.append(int(jnp.argmax(logits[0, -1])))
        return out

    prompts = [rng.integers(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in (5, 9, 7)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    stats = ContinuousBatcher(model, params, cfg, slots=2,
                              max_seq=64).run(reqs)
    assert stats.completed == 3
    for r, p in zip(reqs, prompts):
        assert len(r.generated) == 5, r.rid      # exactly the budget
        assert r.generated == greedy_ref(p, 5), r.rid


def test_batcher_exact_token_accounting(key):
    """Every request emits exactly max_new_tokens tokens (completion is
    checked after every append, admission included) and the counters
    reflect only work actually done."""
    cfg = reduced_config("qwen3-32b")
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    rng = np.random.default_rng(1)
    budgets = [1, 3, 2, 1]
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=4 + i).astype(np.int32),
                    max_new_tokens=m)
            for i, m in enumerate(budgets)]
    b = ContinuousBatcher(model, params, cfg, slots=2, max_seq=64)
    stats = b.run(reqs)
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens, r.rid
        assert r.done
    assert stats.completed == len(reqs)
    assert stats.prefills == len(reqs)
    assert stats.tokens_out == sum(budgets)
    # decode steps only generate the post-prefill tokens; with 2 slots the
    # longest chain (3 tokens -> 2 decodes) bounds the step count, and the
    # two max_new_tokens=1 requests never occupy a decode slot
    assert stats.steps == 2
    assert stats.max_active <= 2


def test_batcher_mnt1_completes_at_admission(key):
    """A max_new_tokens=1 request is satisfied by the prefill-argmax token:
    no decode step runs at all and no slot is ever held."""
    cfg = reduced_config("qwen3-32b")
    model = get_model(cfg)
    params, _ = model.init_params(cfg, key)
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=1)
    b = ContinuousBatcher(model, params, cfg, slots=1, max_seq=64)
    stats = b.run([req])
    assert req.done and len(req.generated) == 1
    assert stats.steps == 0
    assert stats.tokens_out == 1
    assert stats.max_active == 0
    assert stats.completed == 1
    assert all(r is None for r in b.active)
