"""Regression tests for the estimator's float64 scaling.

The bug: `pagerank_from_visits` used to scale the integer visit counters
in float32 (the repo runs with JAX x64 off). float32 is integer-exact only
up to 2**24, so once K*n/eps pushes individual zeta entries past ~16.7M
the cast collapsed *distinct* counters onto the same float — adjacent
vertices with different visit counts got bit-identical pi. The fix scales
on the host in numpy float64.
"""
import numpy as np

from repro.core.estimator import pagerank_from_visits


def test_large_counters_stay_distinct():
    # 2**24 and 2**24 + 1 collide in float32 (the rounding that motivated
    # the fix) but must map to distinct estimates
    z = np.array([2 ** 24, 2 ** 24 + 1], dtype=np.int64)
    assert np.float32(z[0]) == np.float32(z[1])          # f32 would merge
    pi = pagerank_from_visits(z, n=1_000_000, walks_per_node=64, eps=0.1)
    assert pi.dtype == np.float64
    assert pi[0] != pi[1]
    # and the ordering survives
    assert pi[1] > pi[0]


def test_scaling_is_exact_in_float64():
    # zeta * eps / (n*K) reproduced against exact rational arithmetic
    n, K, eps = 4096, 128, 0.25
    z = np.array([0, 1, n * K, 3 * n * K + 7], dtype=np.int64)
    pi = pagerank_from_visits(z, n=n, walks_per_node=K, eps=eps)
    expect = z.astype(np.float64) * (eps / (n * K))
    np.testing.assert_array_equal(pi, expect)
    # the eps/(nK) mass identity at zeta == nK/eps: pi sums to ~1 there
    full = np.full(n, int(K / eps), dtype=np.int64)
    mass = pagerank_from_visits(full, n=n, walks_per_node=K, eps=eps).sum()
    assert abs(mass - 1.0) < 1e-9


def test_accepts_jax_and_numpy_inputs():
    import jax.numpy as jnp
    z32 = jnp.arange(8, dtype=jnp.int32)
    out = pagerank_from_visits(z32, n=8, walks_per_node=2, eps=0.5)
    assert isinstance(out, np.ndarray) and out.dtype == np.float64
    np.testing.assert_allclose(out, np.arange(8) * 0.5 / 16.0)
