"""MoE: gather path vs shard_map data-local path, capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models import get_model
from repro.models.moe import capacity_for, init_moe, moe_forward
from repro.sharding import ShardingRules, active_rules, default_rules


@pytest.fixture(scope="module")
def moe_cfg():
    return reduced_config("dbrx-132b")


def test_sharded_path_matches_gather(moe_cfg, key):
    """On the 1x1 production-axes mesh the shard_map path must equal the
    gather path bit-for-bit (same dispatch, same math)."""
    p, _ = init_moe(key, moe_cfg)
    x = jax.random.normal(key, (2, 16, moe_cfg.d_model)).astype(jnp.bfloat16)
    out_g, aux_g = moe_forward(p, x, moe_cfg)
    rules = ShardingRules(make_local_mesh(), default_rules(False))
    with active_rules(rules):
        out_s, aux_s = moe_forward(p, x, moe_cfg)
    np.testing.assert_array_equal(np.asarray(out_g, np.float32),
                                  np.asarray(out_s, np.float32))
    assert abs(float(aux_g) - float(aux_s)) < 1e-5


def test_capacity_rounding():
    cfg = reduced_config("dbrx-132b")
    small = capacity_for(cfg, 64)
    assert small % 8 == 0
    big_cfg = dataclasses.replace(cfg, capacity_factor=1.25)
    big = capacity_for(big_cfg, 1_000_000)
    assert big % 512 == 0


def test_no_drops_at_high_capacity(moe_cfg, key):
    """capacity_factor 4.0 at smoke scale => every assignment kept: output
    equals a dense per-token mixture computed by brute force."""
    cfg = dataclasses.replace(moe_cfg, num_experts=4, num_experts_per_tok=2,
                              capacity_factor=4.0)
    p, _ = init_moe(key, cfg)
    x = jax.random.normal(key, (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    out, _ = moe_forward(p, x, cfg)
    # brute-force reference
    xf = x.reshape(-1, cfg.d_model)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    w, e = jax.lax.top_k(gates, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf, dtype=jnp.float32)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.float32)
        for j in range(2):
            ei = int(e[t, j])
            up = xf[t].astype(jnp.bfloat16) @ p["w_up"][ei].astype(jnp.bfloat16)
            g = xf[t].astype(jnp.bfloat16) @ p["w_gate"][ei].astype(jnp.bfloat16)
            h = jax.nn.silu(g) * up
            y = h @ p["w_down"][ei].astype(jnp.bfloat16)
            acc += float(w[t, j]) * y.astype(jnp.float32)
        ref = ref.at[t].set(acc)
    got = np.asarray(out.reshape(-1, cfg.d_model), np.float32)
    want = np.asarray(ref, np.float32)
    denom = np.abs(want).max() + 1e-6
    assert np.abs(got - want).max() / denom < 0.05
