"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import barabasi_albert, erdos_renyi
from repro.kernels.histogram import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.segment_spmv import segment_spmv
from repro.kernels.segment_spmv.ref import segment_spmv_ref
from repro.kernels.walk_step import walk_step
from repro.kernels.walk_step.ref import walk_step_ref


@pytest.mark.parametrize("W,n", [(64, 8), (1000, 100), (4096, 512),
                                 (5000, 700), (257, 1), (1, 31)])
def test_histogram_shapes(W, n, key):
    ids = jax.random.randint(key, (W,), -1, n)
    np.testing.assert_array_equal(np.asarray(histogram(ids, n)),
                                  np.asarray(histogram_ref(ids, n)))


def test_histogram_out_of_range(key):
    ids = jnp.array([-5, 0, 3, 99, 3, -1], jnp.int32)
    got = histogram(ids, 4)
    np.testing.assert_array_equal(np.asarray(got), [1, 0, 0, 2])


@pytest.mark.parametrize("block_ids,block_n", [(256, 128), (2048, 512)])
def test_histogram_blockings(block_ids, block_n, key):
    ids = jax.random.randint(key, (3000,), 0, 300)
    from repro.kernels.histogram.histogram import histogram_pallas
    got = histogram_pallas(ids, 300, block_ids=block_ids, block_n=block_n,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(histogram_ref(ids, 300)))


@pytest.mark.parametrize("E,n", [(100, 10), (4000, 300), (999, 50),
                                 (8192, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_shapes(E, n, dtype, key):
    val = jax.random.normal(key, (E,)).astype(dtype)
    dst = jax.random.randint(key, (E,), 0, n)
    got = segment_spmv(val, dst, n)
    want = segment_spmv_ref(val, dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("graph_maker,W", [
    (lambda: erdos_renyi(128, 5.0, seed=1), 1000),
    (lambda: barabasi_albert(200, 3, seed=2), 4096),
])
@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_walk_step_sweep(graph_maker, W, eps, key):
    g = graph_maker()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pos = jax.random.randint(k1, (W,), 0, g.n)
    alive = jax.random.bernoulli(k2, 0.8, (W,))
    ut = jax.random.uniform(k3, (W,))
    ue = jax.random.uniform(k4, (W,))
    a_pos, a_alive = walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                               g.out_deg, eps=eps)
    b_pos, b_alive = walk_step_ref(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                   g.out_deg, eps=eps)
    np.testing.assert_array_equal(np.asarray(a_pos), np.asarray(b_pos))
    np.testing.assert_array_equal(np.asarray(a_alive), np.asarray(b_alive))


def test_walk_step_dead_walks_stay(key):
    g = erdos_renyi(32, 4.0, seed=3)
    pos = jnp.arange(10, dtype=jnp.int32)
    alive = jnp.zeros((10,), bool)
    ut = jnp.zeros((10,))
    ue = jnp.zeros((10,))
    new_pos, new_alive = walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                   g.out_deg, eps=0.3)
    np.testing.assert_array_equal(np.asarray(new_pos), np.asarray(pos))
    assert not np.asarray(new_alive).any()


def test_spmv_powers_power_iteration(small_graphs):
    """segment_spmv wired into power_iteration gives the same pi."""
    from repro.core import power_iteration
    g = small_graphs["er"]
    pi_a, _, _ = power_iteration(g, 0.2, use_pallas=False)
    pi_b, _, _ = power_iteration(g, 0.2, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pi_a), np.asarray(pi_b),
                               rtol=2e-4, atol=1e-7)
