"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import barabasi_albert, erdos_renyi
from repro.kernels.histogram import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.multinomial_rows.multinomial_rows import \
    multinomial_rows_pallas
from repro.kernels.multinomial_rows.ref import multinomial_rows_ref
from repro.kernels.segment_spmv import segment_spmv
from repro.kernels.segment_spmv.ref import segment_spmv_ref
from repro.kernels.walk_step import walk_step
from repro.kernels.walk_step.ref import walk_step_ref


@pytest.mark.parametrize("W,n", [(64, 8), (1000, 100), (4096, 512),
                                 (5000, 700), (257, 1), (1, 31)])
def test_histogram_shapes(W, n, key):
    ids = jax.random.randint(key, (W,), -1, n)
    np.testing.assert_array_equal(np.asarray(histogram(ids, n)),
                                  np.asarray(histogram_ref(ids, n)))


def test_histogram_out_of_range(key):
    ids = jnp.array([-5, 0, 3, 99, 3, -1], jnp.int32)
    got = histogram(ids, 4)
    np.testing.assert_array_equal(np.asarray(got), [1, 0, 0, 2])


@pytest.mark.parametrize("block_ids,block_n", [(256, 128), (2048, 512)])
def test_histogram_blockings(block_ids, block_n, key):
    ids = jax.random.randint(key, (3000,), 0, 300)
    from repro.kernels.histogram.histogram import histogram_pallas
    got = histogram_pallas(ids, 300, block_ids=block_ids, block_n=block_n,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(histogram_ref(ids, 300)))


@pytest.mark.parametrize("E,n", [(100, 10), (4000, 300), (999, 50),
                                 (8192, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_shapes(E, n, dtype, key):
    val = jax.random.normal(key, (E,)).astype(dtype)
    dst = jax.random.randint(key, (E,), 0, n)
    got = segment_spmv(val, dst, n)
    want = segment_spmv_ref(val, dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("graph_maker,W", [
    (lambda: erdos_renyi(128, 5.0, seed=1), 1000),
    (lambda: barabasi_albert(200, 3, seed=2), 4096),
])
@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_walk_step_sweep(graph_maker, W, eps, key):
    g = graph_maker()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pos = jax.random.randint(k1, (W,), 0, g.n)
    alive = jax.random.bernoulli(k2, 0.8, (W,))
    ut = jax.random.uniform(k3, (W,))
    ue = jax.random.uniform(k4, (W,))
    a_pos, a_alive = walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                               g.out_deg, eps=eps)
    b_pos, b_alive = walk_step_ref(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                   g.out_deg, eps=eps)
    np.testing.assert_array_equal(np.asarray(a_pos), np.asarray(b_pos))
    np.testing.assert_array_equal(np.asarray(a_alive), np.asarray(b_alive))


def test_walk_step_dead_walks_stay(key):
    g = erdos_renyi(32, 4.0, seed=3)
    pos = jnp.arange(10, dtype=jnp.int32)
    alive = jnp.zeros((10,), bool)
    ut = jnp.zeros((10,))
    ue = jnp.zeros((10,))
    new_pos, new_alive = walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                   g.out_deg, eps=0.3)
    np.testing.assert_array_equal(np.asarray(new_pos), np.asarray(pos))
    assert not np.asarray(new_alive).any()


def test_spmv_powers_power_iteration(small_graphs):
    """segment_spmv wired into power_iteration gives the same pi."""
    from repro.core import power_iteration
    g = small_graphs["er"]
    pi_a, _, _ = power_iteration(g, 0.2, use_pallas=False)
    pi_b, _, _ = power_iteration(g, 0.2, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pi_a), np.asarray(pi_b),
                               rtol=2e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# engine-shaped inputs: the distributions the distributed engines actually
# feed the kernels (padded lanes, dead walks, dangling resets, integer
# counts), not uniform random sweeps
# ---------------------------------------------------------------------------

def test_histogram_padded_lane_shape(key):
    """Routing-lane shape: mostly -1 padding, valid ids clustered (a lane
    carries one destination shard's vertices)."""
    W, n = 4096, 64
    ids = np.full(W, -1, dtype=np.int32)
    k1, k2 = jax.random.split(key)
    npos = int(jax.random.randint(k1, (), 1, 200))
    ids[:npos] = np.asarray(jax.random.randint(k2, (npos,), 0, n))
    ids = jnp.asarray(ids)
    np.testing.assert_array_equal(np.asarray(histogram(ids, n)),
                                  np.asarray(histogram_ref(ids, n)))


def test_histogram_all_padding():
    ids = jnp.full((512,), -1, jnp.int32)
    np.testing.assert_array_equal(np.asarray(histogram(ids, 16)),
                                  np.zeros(16, np.int32))


def test_spmv_integer_counts_exact():
    """route_counts reduces int32 visit counts through the kernel's f32
    accumulator: sums must stay integer-exact below 2**24."""
    big = 2 ** 23  # two of these sum to 2**24, the last exact f32 integer
    val = jnp.asarray(np.array([big, big, 1, 2, 3], np.float32))
    dst = jnp.asarray(np.array([0, 0, 1, 1, 1], np.int32))
    got = np.asarray(segment_spmv(val, dst, 2))
    np.testing.assert_array_equal(got, [2.0 ** 24, 6.0])


def test_walk_step_dangling_reset(key):
    """Dangling vertices (out-degree 0) must kill the walk on the spot —
    the directed engines' reset convention."""
    # graph: 0 -> 1, 1 dangling
    rp = jnp.asarray([0, 1, 1], jnp.int32)
    ci = jnp.asarray([1], jnp.int32)
    dg = jnp.asarray([1, 0], jnp.int32)
    pos = jnp.asarray([0, 1, 1], jnp.int32)
    alive = jnp.ones((3,), bool)
    ut = jnp.full((3,), 0.99)          # above any eps: no random reset
    ue = jnp.zeros((3,))
    new_pos, new_alive = walk_step(pos, alive, ut, ue, rp, ci, dg, eps=0.2)
    np.testing.assert_array_equal(np.asarray(new_alive), [1, 0, 0])
    assert int(new_pos[0]) == 1


def test_advance_owned_pallas_parity(key):
    """`routing.advance_owned` draws the uniforms once and feeds both
    paths: jnp and the walk_step kernel must agree bit-for-bit on an
    engine-shaped buffer (dead slots, -1 padding, dangling resets)."""
    from repro.core.distributed import shard_graph
    from repro.graphs import directed_web
    from repro.core.routing import advance_owned, count_owned_arrivals

    g = directed_web(96, 5.0, seed=3)
    sg = shard_graph(g, 1)
    rp, ci, dg = sg.row_ptr[0], sg.col_idx[0], sg.out_deg[0]
    k1, k2, kt, ke = jax.random.split(key, 4)
    cap = 512
    pos = jax.random.randint(k1, (cap,), -1, g.n)     # -1 = empty slot
    eligible = (pos >= 0) & jax.random.bernoulli(k2, 0.7, (cap,))
    sid = jnp.int32(0)
    a = advance_owned(rp, ci, dg, pos, eligible, kt, ke, 0.2, sid,
                      sg.n_loc, use_pallas=False)
    b = advance_owned(rp, ci, dg, pos, eligible, kt, ke, 0.2, sid,
                      sg.n_loc, use_pallas=True)
    surv_a, dst_a = np.asarray(a[0]), np.asarray(a[1])
    surv_b, dst_b = np.asarray(b[0]), np.asarray(b[1])
    np.testing.assert_array_equal(surv_a, surv_b)
    # dst is only meaningful where the walk survived
    np.testing.assert_array_equal(dst_a[surv_a], dst_b[surv_b])
    # downstream arrival counting agrees too
    ca = count_owned_arrivals(a[0], dst_a, sid, sg.n_loc, use_pallas=False)
    cb = count_owned_arrivals(b[0], dst_b, sid, sg.n_loc, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))


@pytest.mark.parametrize("R,width,eps", [(64, 4, 0.2), (1000, 8, 0.1),
                                         (4096, 16, 0.5), (257, 1, 0.3),
                                         (1, 32, 0.2)])
def test_multinomial_rows_kernel_matches_ref(R, width, eps, key):
    """The fused termination+split kernel is bit-identical to the jnp
    oracle at every shape (counter RNG: same draws in any blocking)."""
    k1, k2 = jax.random.split(key)
    counts = jax.random.randint(k1, (R,), 0, 5000)
    deg = jax.random.randint(k2, (R,), 0, width + 1)
    rid = jnp.arange(R, dtype=jnp.int32) * 3 + 11
    kw = jnp.asarray(np.array([0xDEADBEEF, 0x12345678], np.uint32))
    got = multinomial_rows_pallas(counts, deg, rid, kw, eps=eps,
                                  width=width, interpret=True)
    want = multinomial_rows_ref(counts, deg, rid, kw, eps=eps, width=width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # conservation: rows whose degree fits the width leak nothing
    T = np.asarray(got)
    np.testing.assert_array_equal(T.sum(axis=1), np.asarray(counts))


@pytest.mark.parametrize("block_r", [256, 1024])
def test_multinomial_rows_blockings(block_r, key):
    """Row-blocking must not change the draws (counter RNG contract)."""
    R, width = 3000, 8
    counts = jax.random.randint(key, (R,), 0, 300)
    deg = jnp.full((R,), 5, jnp.int32)
    rid = jnp.arange(R, dtype=jnp.int32)
    kw = jnp.asarray(np.array([1, 2], np.uint32))
    got = multinomial_rows_pallas(counts, deg, rid, kw, eps=0.2,
                                  width=width, block_r=block_r,
                                  interpret=True)
    want = multinomial_rows_ref(counts, deg, rid, kw, eps=0.2, width=width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


COUNTS_PALLAS_PARITY_CODE = """
import json
import jax, numpy as np
from repro.graphs import barabasi_albert_hub
from repro.core.distributed_counts import distributed_pagerank_counts

g = barabasi_albert_hub(96, 3, seed=4)
runs = {}
for flag in (False, True):
    r = distributed_pagerank_counts(g, 0.2, 100, jax.random.PRNGKey(3),
                                    use_pallas=flag)
    runs[flag] = r
a, b = runs[False], runs[True]
rc = distributed_pagerank_counts(g, 0.2, 100, jax.random.PRNGKey(3),
                                 bucketed=False)
print(json.dumps(dict(
    zeta_equal=bool(np.array_equal(np.asarray(a.zeta), np.asarray(b.zeta))),
    layout_equal=bool(np.array_equal(np.asarray(a.zeta),
                                     np.asarray(rc.zeta))),
    rounds=[a.rounds, b.rounds, rc.rounds],
    residual=[a.residual, b.residual, rc.residual],
    overflow=[a.overflow, b.overflow, rc.overflow])))
"""


def test_counts_engine_pallas_and_layout_bit_parity():
    """The count engine's draws are a pure function of (key, row id,
    slot): the Pallas kernel vs jnp ref AND the bucketed vs flat layout
    must all give bit-identical trajectories on the hub fixture."""
    from conftest import run_forced_devices
    r = run_forced_devices(COUNTS_PALLAS_PARITY_CODE)
    assert r["zeta_equal"] and r["layout_equal"]
    assert len(set(r["rounds"])) == 1
    assert r["residual"] == [0, 0, 0]
    assert r["overflow"] == [0, 0, 0]


ENGINE_PALLAS_PARITY_CODE = """
import json
import jax, numpy as np
from repro.graphs import erdos_renyi
from repro.core.distributed_improved import distributed_improved_pagerank

g = erdos_renyi(96, 5.0, seed=1)
runs = {}
for flag in (False, True):
    r = distributed_improved_pagerank(g, 0.2, walks_per_node=100,
                                      key=jax.random.PRNGKey(7),
                                      use_pallas=flag)
    runs[flag] = r
a, b = runs[False], runs[True]
print(json.dumps(dict(
    zeta_equal=bool(np.array_equal(np.asarray(a.zeta), np.asarray(b.zeta))),
    pi_equal=bool(np.array_equal(np.asarray(a.pi), np.asarray(b.pi))),
    rounds=[a.rounds, b.rounds],
    wire=[a.a2a_bytes_total, b.a2a_bytes_total])))
"""


def test_engine_pallas_bit_parity():
    """The full 3-phase engine is bit-identical with the Pallas hot paths
    on and off: the kernels share decision logic and uniforms with the
    jnp fallbacks, so use_pallas may change *only* the execution path."""
    from conftest import run_forced_devices
    r = run_forced_devices(ENGINE_PALLAS_PARITY_CODE)
    assert r["zeta_equal"] and r["pi_equal"]
    assert r["rounds"][0] == r["rounds"][1]
    assert r["wire"][0] == r["wire"][1]
