"""Sharding rules engine: divisibility, axis reuse, tree shardings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.sharding import ShardingRules, active_rules, default_rules, maybe_constrain


@pytest.fixture(scope="module")
def rules1x1():
    return ShardingRules(make_local_mesh(), default_rules(False))


def test_spec_basic(rules1x1):
    # 1x1 mesh: everything maps but to trivial axes
    s = rules1x1.spec(("batch", "seq", "embed"), (8, 16, 32))
    assert s == P("data", None, None)


def test_spec_divisibility_drop(rules1x1):
    # weights: vocab -> model (TP), embed -> data (FSDP at rest)
    s = rules1x1.spec(("vocab", "embed"), (7, 4))
    assert s == P("model", "data")  # 7 % 1 == 0 on the local mesh


def test_spec_unknown_axis(rules1x1):
    s = rules1x1.spec(("nonexistent", None), (4, 4))
    assert s == P(None, None)


def test_no_axis_reuse(rules1x1):
    # two dims both wanting "model": second one must drop
    s = rules1x1.spec(("vocab", "ffn"), (16, 16))
    assert s == P("model", None)


def test_maybe_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    y = maybe_constrain(x, ("batch", None))
    assert y is x


def test_constrain_inside_context(rules1x1):
    x = jnp.ones((4, 4))
    with active_rules(rules1x1):
        y = maybe_constrain(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tree_shardings(rules1x1):
    shapes = dict(w=jax.ShapeDtypeStruct((8, 4), jnp.float32),
                  b=jax.ShapeDtypeStruct((4,), jnp.float32))
    axes = dict(w=("embed", "ffn"), b=("ffn",))
    sh = rules1x1.tree_shardings(shapes, axes)
    # weights: embed dim FSDP-sharded over data, ffn TP-sharded over model
    assert sh["w"].spec == P("data", "model")
    assert sh["b"].spec == P("model")
