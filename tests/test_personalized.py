"""Personalized PageRank (framework extension) vs dense linear solve."""
import jax
import numpy as np

from repro.core.personalized import exact_ppr, personalized_pagerank
from repro.graphs import barabasi_albert


def test_ppr_matches_linear_solve():
    g = barabasi_albert(80, 3, seed=4)
    eps = 0.25
    seeds = [0, 5, 17]
    est = np.asarray(personalized_pagerank(g, eps, seeds, walks_total=40_000,
                                           key=jax.random.PRNGKey(1)))
    ref = exact_ppr(g, eps, seeds)
    est_n = est / est.sum()
    ref_n = ref / ref.sum()
    assert np.abs(est_n - ref_n).sum() < 0.12
    # mass concentrates near the seed set vs uniform PageRank
    assert est_n[seeds].sum() > 3 * len(seeds) / g.n


def test_ppr_weighted_seeds():
    g = barabasi_albert(60, 3, seed=5)
    eps = 0.3
    est = np.asarray(personalized_pagerank(
        g, eps, [1, 2], walks_total=30_000, weights=[0.9, 0.1],
        key=jax.random.PRNGKey(2)))
    ref = exact_ppr(g, eps, [1, 2], weights=[0.9, 0.1])
    assert np.abs(est / est.sum() - ref / ref.sum()).sum() < 0.12
