"""Personalized PageRank (framework extension) vs dense linear solve."""
import jax
import numpy as np

from repro.core.personalized import (exact_ppr, personalized_pagerank,
                                     source_start_counts)
from repro.graphs import barabasi_albert


def test_ppr_matches_linear_solve():
    g = barabasi_albert(80, 3, seed=4)
    eps = 0.25
    seeds = [0, 5, 17]
    est = np.asarray(personalized_pagerank(g, eps, seeds, walks_total=40_000,
                                           key=jax.random.PRNGKey(1)))
    ref = exact_ppr(g, eps, seeds)
    est_n = est / est.sum()
    ref_n = ref / ref.sum()
    assert np.abs(est_n - ref_n).sum() < 0.12
    # mass concentrates near the seed set vs uniform PageRank
    assert est_n[seeds].sum() > 3 * len(seeds) / g.n


def test_ppr_weighted_seeds():
    g = barabasi_albert(60, 3, seed=5)
    eps = 0.3
    est = np.asarray(personalized_pagerank(
        g, eps, [1, 2], walks_total=30_000, weights=[0.9, 0.1],
        key=jax.random.PRNGKey(2)))
    ref = exact_ppr(g, eps, [1, 2], weights=[0.9, 0.1])
    assert np.abs(est / est.sum() - ref / ref.sum()).sum() < 0.12


def test_start_counts_key_sensitivity():
    """The walk-to-source multinomial is derived from `key`: different
    keys resample the start assignment, same key is bit-reproducible."""
    w = np.array([0.5, 0.3, 0.2])
    a = source_start_counts(jax.random.PRNGKey(0), w, 10_000)
    b = source_start_counts(jax.random.PRNGKey(1), w, 10_000)
    a2 = source_start_counts(jax.random.PRNGKey(0), w, 10_000)
    assert a.sum() == b.sum() == 10_000
    assert not np.array_equal(a, b)       # key actually reaches the draw
    assert np.array_equal(a, a2)          # and deterministically
    # typed keys hit the same stream as legacy raw keys
    t = source_start_counts(jax.random.key(0), w, 10_000)
    assert np.array_equal(a, t)


def test_ppr_key_sensitivity():
    """Same key => bit-identical estimate; different keys => independent
    estimates (both the start multinomial and the walks resample)."""
    g = barabasi_albert(40, 3, seed=6)
    run = lambda k: np.asarray(personalized_pagerank(
        g, 0.3, [0, 7], walks_total=4_000, key=k))
    a = run(jax.random.PRNGKey(0))
    b = run(jax.random.PRNGKey(1))
    a2 = run(jax.random.PRNGKey(0))
    assert np.array_equal(a, a2)
    assert not np.array_equal(a, b)


def test_ppr_max_rounds_cap():
    """`max_rounds` bounds the walk loop: a 1-round run truncates the
    walks (strictly less mass than converged), the default converges."""
    g = barabasi_albert(40, 3, seed=6)
    kw = dict(sources=[0], walks_total=4_000, key=jax.random.PRNGKey(3))
    full = np.asarray(personalized_pagerank(g, 0.3, **kw))
    capped = np.asarray(personalized_pagerank(g, 0.3, max_rounds=1, **kw))
    assert capped.sum() < full.sum()
    # estimator mass ~ eps * E[visits]; the converged run is ~1
    assert 0.9 < full.sum() < 1.1
