"""Wire-accounting regression tests (the `entries * <magic constant>`
bugfix sweep).

Every all_to_all payload in the repo is now charged as
`entries * entry_nbytes(<the actual routed columns>)` instead of a
hand-maintained magic byte count, so the telemetry can never silently
drift from the payload again. These tests pin the helper itself and the
helper-vs-payload agreement of the count-aggregated exchanges.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_forced_devices

from repro.core.routing import entry_nbytes


# ---------------------------------------------------------------------------
# entry_nbytes: derived from dtypes, not hardcoded
# ---------------------------------------------------------------------------

def test_entry_nbytes_single_int32_column():
    assert entry_nbytes(jnp.zeros(4, jnp.int32)) == 4


def test_entry_nbytes_mixed_columns():
    # x64 is off in this repo, so int32/float32 are the widest wire dtypes
    assert entry_nbytes(jnp.zeros(4, jnp.int32),
                        jnp.zeros(4, jnp.int16)) == 6
    assert entry_nbytes(jnp.zeros(4, jnp.int8),
                        jnp.zeros(4, jnp.float32)) == 5


def test_entry_nbytes_field_dict():
    # route_walks charges pos + every routed field by its actual dtype
    fields = dict(cid=jnp.zeros(4, jnp.int32), mode=jnp.zeros(4, jnp.int8))
    assert entry_nbytes(jnp.zeros(4, jnp.int32), fields) == 4 + 4 + 1


def test_entry_nbytes_follows_dtype_change():
    # the regression: a dtype change must move the byte count with it
    assert (entry_nbytes(jnp.zeros(2, jnp.int32))
            == 2 * entry_nbytes(jnp.zeros(2, jnp.int16)))


# ---------------------------------------------------------------------------
# route_counts: conservation + helper-vs-payload agreement
# ---------------------------------------------------------------------------

ROUTE_COUNTS_CODE = """
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.routing import entry_nbytes, route_counts, shard_map

shards = len(jax.devices())
n_loc = 8
n_pad = shards * n_loc
mesh = Mesh(np.array(jax.devices()), ("shards",))

# every shard holds a full per-vertex count vector; deterministic pattern
rng = np.random.default_rng(0)
cnt = rng.integers(0, 5, size=(shards, n_pad)).astype(np.int32)

def local(cv):
    cv = cv[0]
    sid = jax.lax.axis_index("shards")
    arrivals, entries, nbytes = route_counts(
        cv, axis="shards", shard_id=sid, n_loc=n_loc, shards=shards)
    return (arrivals[None],
            jax.lax.psum(entries, "shards"),
            jax.lax.psum(nbytes, "shards"))

fn = shard_map(local, mesh, in_specs=(P("shards"),),
               out_specs=(P("shards"), P(), P()))
arr, entries, nbytes = fn(jax.device_put(
    jnp.asarray(cnt), NamedSharding(mesh, P("shards"))))
arr = np.asarray(arr)

# conservation: every count lands exactly once at its owner
expect = cnt.sum(axis=0).reshape(shards, n_loc)
ok_conserve = bool((arr == expect).all())

# payload agreement: 2 int32 lanes (vertex id + count) = 8 B/entry
per_entry = entry_nbytes(jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32))
ok_bytes = int(nbytes) == int(entries) * per_entry

# entries = nonzero remote cells, an exact count we can recompute on host
owner = np.arange(n_pad) // n_loc
expect_entries = sum(int(((cnt[p] > 0) & (owner != p)).sum())
                     for p in range(shards))
print(json.dumps(dict(ok_conserve=ok_conserve, ok_bytes=ok_bytes,
                      entries=int(entries),
                      expect_entries=expect_entries,
                      per_entry=int(per_entry))))
"""


def test_route_counts_conservation_and_bytes():
    r = run_forced_devices(ROUTE_COUNTS_CODE)
    assert r["ok_conserve"], "route_counts lost or duplicated counts"
    assert r["ok_bytes"], "sent_bytes disagrees with entry_nbytes * entries"
    assert r["entries"] == r["expect_entries"]
    assert r["per_entry"] == 8


ROUTE_COUNTS_BY_SOURCE_CODE = """
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.routing import route_counts, shard_map

shards = len(jax.devices())
n_loc = 8
n_pad = shards * n_loc
mesh = Mesh(np.array(jax.devices()), ("shards",))
rng = np.random.default_rng(1)
cnt = rng.integers(0, 4, size=(shards, n_pad)).astype(np.int32)

def local(cv):
    cv = cv[0]
    sid = jax.lax.axis_index("shards")
    arrivals, entries, nbytes = route_counts(
        cv, axis="shards", shard_id=sid, n_loc=n_loc, shards=shards,
        by_source=True)
    return arrivals[None], jax.lax.psum(entries, "shards")

fn = shard_map(local, mesh, in_specs=(P("shards"),),
               out_specs=(P("shards"), P()))
arr, entries = fn(jax.device_put(
    jnp.asarray(cnt), NamedSharding(mesh, P("shards"))))
arr = np.asarray(arr).reshape(shards, shards, n_loc)

# by_source keeps the (source shard, owned vertex) resolution: owner p's
# row h must be exactly source h's counts for p's vertices
ok = all(bool((arr[p, h] == cnt[h, p * n_loc:(p + 1) * n_loc]).all())
         for p in range(shards) for h in range(shards))
print(json.dumps(dict(ok=ok)))
"""


def test_route_counts_by_source_row_placement():
    r = run_forced_devices(ROUTE_COUNTS_BY_SOURCE_CODE)
    assert r["ok"], "by_source row placement lost the source resolution"


# ---------------------------------------------------------------------------
# end-to-end: engine wire telemetry equals trace entries * bytes-per-entry
# ---------------------------------------------------------------------------

ENGINE_WIRE_CODE = """
import json
import jax, numpy as np
from repro.graphs import erdos_renyi
from repro.core.distributed_improved import distributed_improved_pagerank

g = erdos_renyi(96, 5.0, seed=1)
r = distributed_improved_pagerank(g, 0.2, walks_per_node=100,
                                  key=jax.random.PRNGKey(7))
p1, p2, p3 = r.phase1_rounds, r.phase2_rounds, r.phase3_rounds
traces = [t.messages for t in r.report.traces]
# Phase-2 rounds sit right after Phase 1 in the trace log; each round's
# payload is (vertex, count) pairs of 2 int32 lanes = 8 B/entry
p2_entries = sum(traces[p1:p1 + p2])
p3_entries = sum(traces[p1 + p2:p1 + p2 + p3])
print(json.dumps(dict(
    wire=r.a2a_bytes_by_phase, p2_entries=p2_entries,
    p3_entries=p3_entries, total=r.a2a_bytes_total)))
"""


def test_engine_phase_wire_matches_trace_entries():
    r = run_forced_devices(ENGINE_WIRE_CODE)
    assert r["wire"]["phase2"] == 8 * r["p2_entries"]
    assert r["wire"]["phase3"] == 8 * r["p3_entries"]
    assert r["total"] == sum(r["wire"].values())
    # the report phase is gone entirely under count aggregation
    assert r["wire"]["report"] == 0
