"""Shared Pallas kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jnp.ndarray, multiple: int, axis: int = 0, fill=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = cdiv(size, multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad, constant_values=fill)


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU (this container) run the kernel
    body in interpret mode — identical semantics, Python execution."""
    return jax.default_backend() != "tpu"


def resolve_use_pallas(flag=None) -> bool:
    """Resolve an engine's `use_pallas` argument: an explicit True/False
    wins; `None` defers to the REPRO_USE_PALLAS environment variable
    (1/true/yes/on, case-insensitive), default off. Lets CI flip the whole
    engine matrix onto the kernel paths without threading a flag through
    every entry point."""
    if flag is not None:
        return bool(flag)
    import os
    return os.environ.get("REPRO_USE_PALLAS", "").strip().lower() in (
        "1", "true", "yes", "on")
