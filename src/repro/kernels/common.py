"""Shared Pallas kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jnp.ndarray, multiple: int, axis: int = 0, fill=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = cdiv(size, multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad, constant_values=fill)


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU (this container) run the kernel
    body in interpret mode — identical semantics, Python execution."""
    return jax.default_backend() != "tpu"
