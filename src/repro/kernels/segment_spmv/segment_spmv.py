"""Segment-sum SpMV Pallas kernel — the power-iteration push.

Power iteration (the baseline the paper compares against) is dominated by
the CSR push  y[dst_e] += val_e. On TPU the scatter becomes a blocked
one-hot *matmul* so the reduction runs on the MXU:

    partial[j] = sum_e val_e * 1[dst_e == base + j]
               = val_block  @ onehot(dst_block)        # [1,bm] @ [bm,bn]

Grid: (vertex_blocks, edge_blocks) with edge blocks minormost, accumulating
into the resident output tile. Edge values/ids are padded with dst = -1
(never matches). fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


DEFAULT_BLOCK_E = 2048
DEFAULT_BLOCK_N = 512


def _spmv_kernel(val_ref, dst_ref, out_ref, *, block_n: int):
    ni = pl.program_id(0)
    ei = pl.program_id(1)
    val = val_ref[...].astype(jnp.float32)      # [be]
    dst = dst_ref[...]                          # [be]
    base = ni * block_n
    local = dst - base
    iota = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], block_n), 1)
    onehot = (local[:, None] == iota).astype(jnp.float32)   # [be, bn]
    partial = jnp.dot(val[None, :], onehot,
                      preferred_element_type=jnp.float32)[0]  # MXU

    @pl.when(ei == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ei != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "block_e", "block_n",
                                    "interpret"))
def segment_spmv_pallas(values: jnp.ndarray, dst: jnp.ndarray,
                        num_segments: int, *,
                        block_e: int = DEFAULT_BLOCK_E,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = True) -> jnp.ndarray:
    """y[v] = sum over edges e with dst[e]==v of values[e]  (fp32)."""
    E = values.shape[0]
    block_e = min(block_e, max(256, E))
    n_pad = cdiv(num_segments, block_n) * block_n
    e_pad = cdiv(max(E, 1), block_e) * block_e
    val_p = jnp.zeros((e_pad,), values.dtype).at[:E].set(values)
    dst_p = jnp.full((e_pad,), -1, jnp.int32).at[:E].set(dst.astype(jnp.int32))
    grid = (n_pad // block_n, e_pad // block_e)
    out = pl.pallas_call(
        functools.partial(_spmv_kernel, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_e,), lambda ni, ei: (ei,)),
                  pl.BlockSpec((block_e,), lambda ni, ei: (ei,))],
        out_specs=pl.BlockSpec((block_n,), lambda ni, ei: (ni,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(val_p, dst_p)
    return out[:num_segments]
