"""Jitted public wrapper: Pallas on TPU, interpret elsewhere.

The kernel accumulates in float32, which is exact for integer values only
up to 2**24 (the f32 mantissa). Integer inputs therefore go through a
guarded cast: callers declare the largest count a segment sum can reach
via `count_bound`, and when that bound exceeds the f32 exact-integer
range the reduction is widened to an exact integer `segment_sum` instead
of silently truncating (the PR-7 sampler-precision bug class). With no
declared bound, or a bound within range, integer inputs take the same
f32 kernel path as before, bit-identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.segment_spmv.segment_spmv import segment_spmv_pallas

# largest integer float32 represents exactly (24 mantissa bits)
F32_EXACT_MAX = 2 ** 24


def segment_spmv(values: jnp.ndarray, dst: jnp.ndarray, num_segments: int,
                 *, count_bound=None, **kw) -> jnp.ndarray:
    kw.setdefault("interpret", default_interpret())
    if jnp.issubdtype(values.dtype, jnp.integer):
        if count_bound is not None and int(count_bound) > F32_EXACT_MAX:
            # f32 accumulation can no longer represent every partial sum
            # exactly — widen to an exact integer segment_sum (same
            # out-of-range drop semantics as the kernel: invalid ids hit
            # a discarded overflow segment).
            seg = jnp.where((dst >= 0) & (dst < num_segments), dst,
                            num_segments)
            return jax.ops.segment_sum(
                values, seg, num_segments=num_segments + 1)[:num_segments]
        return segment_spmv_pallas(values.astype(jnp.float32), dst,
                                   num_segments, **kw).astype(values.dtype)
    return segment_spmv_pallas(values, dst, num_segments, **kw)
