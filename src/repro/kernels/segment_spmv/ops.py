"""Jitted public wrapper: Pallas on TPU, interpret elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.segment_spmv.segment_spmv import segment_spmv_pallas


def segment_spmv(values: jnp.ndarray, dst: jnp.ndarray, num_segments: int,
                 **kw) -> jnp.ndarray:
    kw.setdefault("interpret", default_interpret())
    return segment_spmv_pallas(values, dst, num_segments, **kw)
