"""Pure-jnp oracle for segment_spmv."""
import jax
import jax.numpy as jnp


def segment_spmv_ref(values: jnp.ndarray, dst: jnp.ndarray,
                     num_segments: int) -> jnp.ndarray:
    valid = (dst >= 0) & (dst < num_segments)
    return jax.ops.segment_sum(
        jnp.where(valid, values.astype(jnp.float32), 0.0),
        jnp.where(valid, dst, num_segments),
        num_segments=num_segments + 1,
    )[:num_segments]
