from repro.kernels.segment_spmv.ops import segment_spmv

__all__ = ["segment_spmv"]
