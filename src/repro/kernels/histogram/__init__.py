from repro.kernels.histogram.ops import histogram

__all__ = ["histogram"]
