"""Visit-count histogram Pallas kernel (TPU one-hot reduction).

The PageRank engines increment per-vertex visit counters with a histogram of
walk arrival positions every super-step. A data-dependent scatter is hostile
to the TPU's vector/matrix units, so the TPU-native formulation is a blocked
one-hot reduction:

    counts[v] = sum_w 1[ids_w == v]

Grid: (vertex_blocks, id_blocks); for a fixed vertex block the id blocks
iterate minormost and accumulate into the same VMEM output tile, so each
output tile is written once. ids == -1 (dead/masked walks) never match and
are naturally dropped. Block sizes are lane-aligned (multiples of 128) for
the 8x128 VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


DEFAULT_BLOCK_IDS = 2048
DEFAULT_BLOCK_N = 512


def _hist_kernel(ids_ref, out_ref, *, block_n: int):
    ni = pl.program_id(0)
    wi = pl.program_id(1)
    ids = ids_ref[...]                      # [block_ids] int32
    base = ni * block_n
    local = ids - base                      # [-inf..) ; matches only in-range
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_n), 1)
    onehot = (local[:, None] == iota).astype(jnp.int32)
    partial = jnp.sum(onehot, axis=0)       # [block_n]

    @pl.when(wi == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(wi != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "block_ids", "block_n",
                                    "interpret"))
def histogram_pallas(ids: jnp.ndarray, num_segments: int, *,
                     block_ids: int = DEFAULT_BLOCK_IDS,
                     block_n: int = DEFAULT_BLOCK_N,
                     interpret: bool = True) -> jnp.ndarray:
    """counts[v] = |{w : ids[w] == v}| for v in [0, num_segments).

    ids entries outside [0, num_segments) are ignored (use -1 to mask).
    """
    W = ids.shape[0]
    block_ids = min(block_ids, max(256, W))
    n_pad = cdiv(num_segments, block_n) * block_n
    w_pad = cdiv(max(W, 1), block_ids) * block_ids
    ids_p = jnp.full((w_pad,), -1, jnp.int32).at[:W].set(ids.astype(jnp.int32))
    grid = (n_pad // block_n, w_pad // block_ids)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_ids,), lambda ni, wi: (wi,))],
        out_specs=pl.BlockSpec((block_n,), lambda ni, wi: (ni,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(ids_p)
    return out[:num_segments]
