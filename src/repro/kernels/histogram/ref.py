"""Pure-jnp oracle for the histogram kernel."""
import jax
import jax.numpy as jnp


def histogram_ref(ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    valid = (ids >= 0) & (ids < num_segments)
    return jax.ops.segment_sum(
        valid.astype(jnp.int32),
        jnp.where(valid, ids, num_segments),
        num_segments=num_segments + 1,
    )[:num_segments].astype(jnp.int32)
