"""Jitted public wrapper: Pallas on TPU, interpret elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.histogram.histogram import histogram_pallas


def histogram(ids: jnp.ndarray, num_segments: int, **kw) -> jnp.ndarray:
    kw.setdefault("interpret", default_interpret())
    return histogram_pallas(ids, num_segments, **kw)
