"""Jitted public wrapper: Pallas on TPU, interpret elsewhere."""
from __future__ import annotations

from repro.kernels.common import default_interpret
from repro.kernels.walk_step.walk_step import walk_step_pallas


def walk_step(pos, alive, u_term, u_edge, row_ptr, col_idx, out_deg, *,
              eps: float, **kw):
    kw.setdefault("interpret", default_interpret())
    return walk_step_pallas(pos, alive, u_term, u_edge, row_ptr, col_idx,
                            out_deg, eps=eps, **kw)
