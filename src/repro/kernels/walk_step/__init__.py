from repro.kernels.walk_step.ops import walk_step

__all__ = ["walk_step"]
