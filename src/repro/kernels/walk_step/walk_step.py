"""Fused PageRank walk-step Pallas kernel.

One engine super-step per walk block, fused in VMEM:
    terminate?  u_term < eps  (or dangling)          — VPU compare
    edge pick   j = floor(u_edge * deg[pos])          — gather + VPU
    advance     dst = col[row_ptr[pos] + j]           — two gathers

The graph tables (row_ptr, col_idx, out_deg) are mapped whole into VMEM
(BlockSpec with a constant index_map); walk arrays stream through in blocks.
This is the right TPU shape for per-shard graphs up to a few tens of MB of
CSR — beyond that, the distributed engine shards vertices across chips
before the kernel ever sees them (see core/distributed.py).

Randomness enters as precomputed uniforms so the kernel is a deterministic
function (replay/restart stay bit-exact, and the ref oracle is trivially
comparable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


DEFAULT_BLOCK_W = 4096


def _walk_kernel(pos_ref, alive_ref, uterm_ref, uedge_ref,
                 row_ptr_ref, col_ref, deg_ref,
                 newpos_ref, newalive_ref, *, eps: float):
    pos = pos_ref[...]                       # [bw] int32
    alive = alive_ref[...] != 0
    u_term = uterm_ref[...]
    u_edge = uedge_ref[...]
    deg_tab = deg_ref[...]
    rp_tab = row_ptr_ref[...]
    col_tab = col_ref[...]

    safe_pos = jnp.clip(pos, 0, deg_tab.shape[0] - 1)
    deg = jnp.take(deg_tab, safe_pos)
    survive = alive & (u_term >= eps) & (deg > 0)
    j = jnp.minimum((u_edge * jnp.maximum(deg, 1).astype(u_edge.dtype))
                    .astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
    eid = jnp.clip(jnp.take(rp_tab, safe_pos) + j, 0, col_tab.shape[0] - 1)
    dst = jnp.take(col_tab, eid)
    newpos_ref[...] = jnp.where(survive, dst, pos)
    newalive_ref[...] = survive.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("eps", "block_w", "interpret"))
def walk_step_pallas(pos: jnp.ndarray, alive: jnp.ndarray,
                     u_term: jnp.ndarray, u_edge: jnp.ndarray,
                     row_ptr: jnp.ndarray, col_idx: jnp.ndarray,
                     out_deg: jnp.ndarray, *, eps: float,
                     block_w: int = DEFAULT_BLOCK_W,
                     interpret: bool = True):
    """Returns (new_pos [W] int32, new_alive [W] int32/bool-ish)."""
    W = pos.shape[0]
    block_w = min(block_w, max(256, W))
    w_pad = cdiv(max(W, 1), block_w) * block_w
    pad = lambda x, fill: jnp.full((w_pad,), fill, x.dtype).at[:W].set(x)
    grid = (w_pad // block_w,)
    whole = lambda arr: pl.BlockSpec(arr.shape, lambda wi: (0,) * arr.ndim)
    new_pos, new_alive = pl.pallas_call(
        functools.partial(_walk_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w,), lambda wi: (wi,)),  # pos
            pl.BlockSpec((block_w,), lambda wi: (wi,)),  # alive
            pl.BlockSpec((block_w,), lambda wi: (wi,)),  # u_term
            pl.BlockSpec((block_w,), lambda wi: (wi,)),  # u_edge
            whole(row_ptr), whole(col_idx), whole(out_deg),
        ],
        out_specs=(pl.BlockSpec((block_w,), lambda wi: (wi,)),
                   pl.BlockSpec((block_w,), lambda wi: (wi,))),
        out_shape=(jax.ShapeDtypeStruct((w_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((w_pad,), jnp.int32)),
        interpret=interpret,
    )(pad(pos.astype(jnp.int32), 0), pad(alive.astype(jnp.int32), 0),
      pad(u_term, 1.0), pad(u_edge, 0.0), row_ptr, col_idx, out_deg)
    return new_pos[:W], new_alive[:W]
