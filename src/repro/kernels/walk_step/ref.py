"""Pure-jnp oracle for the fused walk step."""
import jax.numpy as jnp


def walk_step_ref(pos, alive, u_term, u_edge, row_ptr, col_idx, out_deg, *,
                  eps: float):
    alive = alive.astype(bool)
    safe_pos = jnp.clip(pos, 0, out_deg.shape[0] - 1)
    deg = out_deg[safe_pos]
    survive = alive & (u_term >= eps) & (deg > 0)
    j = jnp.minimum((u_edge * jnp.maximum(deg, 1).astype(u_edge.dtype))
                    .astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
    eid = jnp.clip(row_ptr[safe_pos] + j, 0, col_idx.shape[0] - 1)
    dst = col_idx[eid]
    new_pos = jnp.where(survive, dst, pos)
    return new_pos.astype(jnp.int32), survive.astype(jnp.int32)
