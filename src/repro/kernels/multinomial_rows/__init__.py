from repro.kernels.multinomial_rows.ops import multinomial_rows

__all__ = ["multinomial_rows"]
