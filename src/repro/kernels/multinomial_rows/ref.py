"""Pure-jnp oracle for the fused aggregate-multinomial sampler."""
from __future__ import annotations

import functools

import jax

from repro.kernels.multinomial_rows._math import sample_rows_math


@functools.partial(jax.jit, static_argnames=("eps", "width"))
def multinomial_rows_ref(counts, deg, rid, key_words, *, eps: float,
                         width: int):
    """T [R, width+1] int32; column 0 = terminations, 1+j = out-edge j.

    Same counter-RNG math as the Pallas kernel (`_math.sample_rows_math`),
    evaluated over the whole row vector at once.
    """
    return sample_rows_math(counts, deg, rid, key_words[0], key_words[1],
                            eps=eps, width=width)
