"""Shared sampling math for the fused aggregate-multinomial kernel.

Everything here is plain jnp on arrays, so the SAME functions run inside
the Pallas kernel body and in the pure-jnp oracle — `use_pallas` switches
only the execution path, never the draws, which keeps the engines
bit-identical across the flag (the repo-wide kernel contract, see
`tests/test_kernels.py::test_engine_pallas_bit_parity`).

RNG contract — counter-based, per row:
  u(row, t) = u01(fmix32(fmix32((rid * C1) ^ k0) + ((t * C2) ^ k1)))
where `rid` is the caller-supplied globally-unique row id, `t` the draw
index within the row (0 = the eps-termination draw, j+1 = chain slot j),
and (k0, k1) the two uint32 words of a per-round PRNG key. Draws are pure
functions of (k0, k1, rid, t): no split-chain threading, so rows sample
independently in any blocking/order — exactly what a row-blocked kernel
needs — and replay/checkpoint-recovery stays bit-exact.

Binomial(n, p) from ONE uniform (hybrid, complement-flipped so pp <= 1/2):
  * n*pp <= 10 — BINV inverse-CDF walk (exact CDF inversion, truncated at
    `_BINV_ITERS`; the neglected tail mass is < 1e-15 at mean 10);
  * n*pp  > 10 — normal approximation with the Acklam inverse-normal.
The endpoints are EXACT in integer arithmetic: p == 0 returns 0 and
p == 1 returns n itself (never n routed through float32) — this is what
makes the conditional-binomial chain conserve mass bit-exactly at any
count magnitude, fixing the former `jax.random.binomial(k, c.astype(f32))`
truncation for counts above 2**24 (see tests/test_sampler_precision.py).
The normal branch evaluates means in float32, so marginals for counts
beyond 2**24 carry a ~1e-7 relative mean error — statistical, never a
conservation leak.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_BINV_ITERS = 48
_BINV_MEAN_MAX = 10.0


def _u32(x):
    if isinstance(x, int):
        return jnp.uint32(np.uint32(x))
    return jnp.asarray(x).astype(jnp.uint32)


def _fmix32(x):
    """murmur3 finalizer: full-avalanche 32-bit hash."""
    x = x ^ (x >> _u32(16))
    x = x * _u32(0x85EBCA6B)
    x = x ^ (x >> _u32(13))
    x = x * _u32(0xC2B2AE35)
    x = x ^ (x >> _u32(16))
    return x


def counter_u01(rid, t, k0, k1):
    """Uniform in (0, 1), a pure function of (k0, k1, rid, t)."""
    h = _fmix32((_u32(rid) * _u32(0x9E3779B1)) ^ _u32(k0))
    h = _fmix32(h + ((_u32(t) * _u32(0x85EBCA77)) ^ _u32(k1)))
    # 24 mantissa bits, offset half a ulp: strictly inside (0, 1)
    return ((h >> _u32(8)).astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -24)


def _ndtri(u):
    """Acklam's rational approximation to the inverse normal CDF."""
    u = jnp.clip(u, 1e-7, 1.0 - 1e-7)
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow = 0.02425
    # central region
    q = u - 0.5
    r = q * q
    num = ((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]
    den = ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1.0
    x_mid = q * num / den
    # lower tail (upper tail by symmetry)
    ul = jnp.minimum(u, 1.0 - u)
    ql = jnp.sqrt(-2.0 * jnp.log(ul))
    numt = ((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql \
        + c[5]
    dent = (((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1.0
    x_tail = numt / dent
    x_tail = jnp.where(u < 0.5, x_tail, -x_tail)
    tail = (u < plow) | (u > 1.0 - plow)
    return jnp.where(tail, x_tail, x_mid).astype(jnp.float32)


def binomial_counter(n, p, u):
    """X ~ Binomial(n, p) from one uniform. n int32 >= 0, p float32.

    Endpoint-exact (p==0 -> 0, p==1 -> n, in int arithmetic); hybrid
    BINV / normal elsewhere — see the module docstring.
    """
    n = n.astype(jnp.int32)
    n_f = n.astype(jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    flip = p > 0.5
    pp = jnp.where(flip, 1.0 - p, p)
    mean = n_f * pp

    # --- BINV: count how many prefix-CDF values u clears ---
    q = pp / jnp.maximum(1.0 - pp, 0.5)       # pp <= 0.5 so 1-pp >= 0.5
    pdf0 = jnp.exp(n_f * jnp.log1p(-pp))
    x0 = jnp.zeros_like(n)

    def body(k, carry):
        pdf, cdf, x = carry
        kf = k.astype(jnp.float32)
        x = x + (u > cdf).astype(jnp.int32)
        pdf = pdf * ((n_f - kf + 1.0) / kf) * q
        cdf = cdf + pdf
        return pdf, cdf, x

    _, _, x_small = jax.lax.fori_loop(1, _BINV_ITERS + 1, body,
                                      (pdf0, pdf0, x0))

    # --- normal approximation with continuity correction ---
    sd = jnp.sqrt(jnp.maximum(mean * (1.0 - pp), 1e-12))
    x_norm = jnp.floor(mean + sd * _ndtri(u) + 0.5).astype(jnp.int32)

    x = jnp.where(mean <= _BINV_MEAN_MAX, x_small, x_norm)
    x = jnp.clip(x, 0, n)
    return jnp.where(flip, n - x, x)


def sample_rows_math(counts, deg, rid, k0, k1, *, eps: float, width: int):
    """Fused termination + conditional-binomial chain for a block of rows.

    counts/deg/rid: [R] int32. Returns T [R, width+1] int32 where column 0
    is the termination count (a dangling row — deg == 0 — terminates
    whole) and column 1+j the count sent down out-edge slot j. Rows with
    deg <= width conserve mass exactly: T.sum(1) == counts, because the
    last live slot draws p == 1 (endpoint-exact) and every draw is
    clipped to [0, remaining].
    """
    counts = counts.astype(jnp.int32)
    deg = deg.astype(jnp.int32)
    u_t = counter_u01(rid, 0, k0, k1)
    term = jnp.where(deg > 0,
                     binomial_counter(counts, jnp.float32(eps), u_t),
                     counts)
    rem0 = counts - term

    def body(rem, j):
        u = counter_u01(rid, j + 1, k0, k1)
        slots = jnp.maximum(deg - j, 1).astype(jnp.float32)
        p = jnp.where(j < deg, 1.0 / slots, 0.0)
        t = jnp.minimum(binomial_counter(rem, p, u), rem)
        return rem - t, t

    _, T = jax.lax.scan(body, rem0, jnp.arange(width, dtype=jnp.int32))
    return jnp.concatenate([term[:, None], T.T], axis=1)


def key_words(key):
    """(k0, k1) uint32 words of a legacy PRNGKey array."""
    kw = jnp.asarray(key).astype(jnp.uint32).reshape(-1)
    return kw[:2]
