"""Fused aggregate-multinomial Pallas kernel.

One degree bucket per call: every row draws its Binomial(eps) termination
and splits the survivors over `width` out-edge slots with the
conditional-binomial chain, fused in VMEM. The engines call it once per
power-of-two degree bucket (see `core/aggregate_sampler.py`), so the chain
scans the bucket width — at most 2x the row's degree — instead of the
global max degree.

Rows are independent by construction (counter-based RNG keyed on the
caller's row id, see `_math`), so the grid streams row blocks with no
cross-block state; the only whole-mapped input is the 2-word PRNG key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv
from repro.kernels.multinomial_rows._math import sample_rows_math

DEFAULT_BLOCK_R = 2048


def _mn_kernel(c_ref, deg_ref, rid_ref, kw_ref, out_ref, *, eps: float,
               width: int):
    kw = kw_ref[...]
    out_ref[...] = sample_rows_math(c_ref[...], deg_ref[...], rid_ref[...],
                                    kw[0], kw[1], eps=eps, width=width)


@functools.partial(jax.jit,
                   static_argnames=("eps", "width", "block_r", "interpret"))
def multinomial_rows_pallas(counts, deg, rid, key_words, *, eps: float,
                            width: int, block_r: int = DEFAULT_BLOCK_R,
                            interpret: bool = True):
    """T [R, width+1] int32; column 0 = terminations, 1+j = out-edge j."""
    R = counts.shape[0]
    block_r = min(block_r, max(256, R))
    r_pad = cdiv(max(R, 1), block_r) * block_r
    pad = lambda x: jnp.zeros((r_pad,), jnp.int32).at[:R].set(
        x.astype(jnp.int32))
    grid = (r_pad // block_r,)
    out = pl.pallas_call(
        functools.partial(_mn_kernel, eps=eps, width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),   # counts
            pl.BlockSpec((block_r,), lambda i: (i,)),   # deg
            pl.BlockSpec((block_r,), lambda i: (i,)),   # rid
            pl.BlockSpec((2,), lambda i: (0,)),         # key words (whole)
        ],
        out_specs=pl.BlockSpec((block_r, width + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, width + 1), jnp.int32),
        interpret=interpret,
    )(pad(counts), pad(deg), pad(rid), key_words.astype(jnp.uint32))
    return out[:R]
