"""Jitted public wrapper: Pallas on TPU, interpret elsewhere."""
from __future__ import annotations

from repro.kernels.common import default_interpret
from repro.kernels.multinomial_rows.multinomial_rows import (
    multinomial_rows_pallas)


def multinomial_rows(counts, deg, rid, key_words, *, eps: float, width: int,
                     **kw):
    kw.setdefault("interpret", default_interpret())
    return multinomial_rows_pallas(counts, deg, rid, key_words, eps=eps,
                                   width=width, **kw)
