"""Pallas TPU kernels for the paper's compute hot-spots.

  histogram         — visit-count one-hot reduction (engine super-steps)
  segment_spmv      — one-hot-MXU CSR push (power-iteration baseline)
  walk_step         — fused terminate/select/advance walk step
  multinomial_rows  — fused Binomial-termination + conditional-binomial
                      aggregate multinomial over a degree bucket

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret on CPU), ref.py (pure-jnp oracle).
"""
from repro.kernels.common import resolve_use_pallas
from repro.kernels.histogram import histogram
from repro.kernels.multinomial_rows import multinomial_rows
from repro.kernels.segment_spmv import segment_spmv
from repro.kernels.walk_step import walk_step

__all__ = ["histogram", "multinomial_rows", "resolve_use_pallas",
           "segment_spmv", "walk_step"]
