"""Decoder-only transformer LM (dense + MoE), scan-over-layers.

Layer params are stacked on a leading "layers" axis and consumed by
`lax.scan` (O(1) compile time in depth) with full per-layer remat for
training. MoE architectures with leading dense layers (DeepSeek-V2) keep two
stacks: `dense_layers` then `moe_layers`, preserving layer order.

Exports (used by registry/launch):
  init_params(cfg, key)          -> (params, axes)
  loss_fn(params, batch, cfg)    -> (loss, metrics)     [train_step target]
  prefill(params, tokens, cfg)   -> (logits_last, cache)
  decode_step(params, cache, token, cfg) -> (logits, cache)
  init_cache(cfg, batch, max_seq) -> (cache, axes)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.common import ckpt, maybe_scan
from repro.models.common import (COMPUTE_DTYPE, cross_entropy, dense_init,
                                 embed, init_embedding, prepend_layers_axis,
                                 rms_norm, stack_init, unembed, zeros_init)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.sharding.rules import maybe_constrain


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_attn(key, cfg):
    if cfg.attention == "mla":
        return attn_lib.init_mla(key, cfg)
    return attn_lib.init_gqa(key, cfg)


def init_block(key, cfg, *, moe: bool):
    k1, k2 = jax.random.split(key)
    ap, aa = _init_attn(k1, cfg)
    p = dict(ln1=zeros_init((cfg.d_model,)), attn=ap,
             ln2=zeros_init((cfg.d_model,)))
    a = dict(ln1=("embed",), attn=aa, ln2=("embed",))
    if moe:
        mp, ma = init_moe(k2, cfg)
        p["moe"], a["moe"] = mp, ma
    else:
        mp, ma = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp)
        p["mlp"], a["mlp"] = mp, ma
    return p, a


def block_forward(p, x, cfg, positions, *, moe: bool, q_chunk: int = 512):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        h = attn_lib.mla_forward(p["attn"], h, cfg, positions, q_chunk=q_chunk)
    else:
        h = attn_lib.gqa_forward(p["attn"], h, cfg, positions, q_chunk=q_chunk)
    x = x + h
    x = maybe_constrain(x, ("batch", "seq", "embed"))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        h, aux = moe_forward(p["moe"], h, cfg)
    else:
        h, aux = mlp_forward(p["mlp"], h, cfg.mlp), jnp.float32(0)
    x = x + h
    return maybe_constrain(x, ("batch", "seq", "embed")), aux


def block_decode(p, x, cfg, cache, *, moe: bool):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        h, cache = attn_lib.mla_decode(p["attn"], h, cfg, cache)
    else:
        h, cache = attn_lib.gqa_decode(p["attn"], h, cfg, cache)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        h, _ = moe_forward(p["moe"], h, cfg)
    else:
        h = mlp_forward(p["mlp"], h, cfg.mlp)
    return x + h, cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _layer_split(cfg) -> Tuple[int, int]:
    """(n_dense_layers, n_moe_layers)."""
    if cfg.num_experts:
        return cfg.first_dense_layers, cfg.num_layers - cfg.first_dense_layers
    return cfg.num_layers, 0


def init_params(cfg, key):
    n_dense, n_moe = _layer_split(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model)
    if n_dense:
        p["dense_layers"], a["dense_layers"] = stack_init(
            lambda k: init_block(k, cfg, moe=False), ks[1], n_dense)
    if n_moe:
        p["moe_layers"], a["moe_layers"] = stack_init(
            lambda k: init_block(k, cfg, moe=True), ks[2], n_moe)
    p["final_norm"] = zeros_init((cfg.d_model,))
    a["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = init_embedding(ks[3], cfg.vocab_size,
                                                    cfg.d_model)
    return p, a


def _scan_stack(layers_params, x, fn, *, remat: bool):
    f = ckpt(fn) if remat else fn

    def body(carry, lp):
        x, aux = carry
        x2, a = f(lp, x)
        return (x2, aux + a), None

    (x, aux), _ = maybe_scan(body, (x, jnp.float32(0)), layers_params)
    return x, aux


def forward_hidden(params, x, cfg, positions, *, remat: bool = True,
                   q_chunk: int = 512):
    """x: [B, T, d] input embeddings -> (hidden [B,T,d], aux_loss)."""
    aux_total = jnp.float32(0)
    if "dense_layers" in params:
        x, aux = _scan_stack(
            params["dense_layers"], x,
            lambda lp, h: block_forward(lp, h, cfg, positions, moe=False,
                                        q_chunk=q_chunk),
            remat=remat)
        aux_total += aux
    if "moe_layers" in params:
        x, aux = _scan_stack(
            params["moe_layers"], x,
            lambda lp, h: block_forward(lp, h, cfg, positions, moe=True,
                                        q_chunk=q_chunk),
            remat=remat)
        aux_total += aux
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total


def logits_fn(params, hidden, cfg):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden)


def loss_fn(params, batch, cfg, *, aux_coef: float = 0.01,
            q_chunk: int = 512):
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    hidden, aux = forward_hidden(params, x, cfg, positions, q_chunk=q_chunk)
    logits = logits_fn(params, hidden, cfg)
    ce = cross_entropy(logits, labels)
    return ce + aux_coef * aux, dict(ce=ce, aux=aux)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int):
    n_dense, n_moe = _layer_split(cfg)
    if cfg.attention == "mla":
        c1, ax = attn_lib.init_mla_cache(cfg, batch, max_seq)
    else:
        c1, ax = attn_lib.init_gqa_cache(cfg, batch, max_seq)

    def stack(c, n):
        return jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v, (n,) + v.shape).copy(), c)

    cache = {}
    axes = {}
    if n_dense:
        cache["dense"] = stack(c1, n_dense)
        axes["dense"] = prepend_layers_axis(ax)
    if n_moe:
        cache["moe"] = stack(c1, n_moe)
        axes["moe"] = prepend_layers_axis(ax)
    return cache, axes


def prefill(params, tokens, cfg, *, q_chunk: int = 512,
            pad_cache_to: Optional[int] = None):
    """Full-sequence forward; returns last-position logits + filled cache.

    The cache is rebuilt from the layer K/V projections — implemented as a
    second lightweight pass per layer inside the same scan (XLA CSEs the
    shared projections). `pad_cache_to` grows the cache to decode capacity."""
    B, T = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.arange(T, dtype=jnp.int32)

    caches = {}

    def make_fn(moe_flag):
        def fn(lp, h):
            out, aux = block_forward(lp, h, cfg, positions, moe=moe_flag,
                                     q_chunk=q_chunk)
            # cache contents: recompute K/V (or latents) at full seq
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            if cfg.attention == "mla":
                c_kv, k_rope = attn_lib._mla_kv_latent(
                    lp["attn"], hn, cfg, positions[None, :])
                c = dict(c_kv=c_kv, k_rope=k_rope,
                         idx=jnp.full((hn.shape[0],), T, jnp.int32))
            else:
                _, k, v = attn_lib._qkv(lp["attn"], hn, cfg, positions[None, :])
                if cfg.sliding_window and cfg.sliding_window < T:
                    k = k[:, -cfg.sliding_window:]
                    v = v[:, -cfg.sliding_window:]
                c = dict(k=k, v=v, idx=jnp.full((k.shape[0],), T, jnp.int32))
            return out, (aux, c)
        return fn

    def scan_fill(stack_params, x, moe_flag):
        fn = make_fn(moe_flag)

        def body(h, lp):
            h2, (aux, c) = fn(lp, h)
            return h2, c

        return maybe_scan(body, x, stack_params)

    if "dense_layers" in params:
        x, caches["dense"] = scan_fill(params["dense_layers"], x, False)
    if "moe_layers" in params:
        x, caches["moe"] = scan_fill(params["moe_layers"], x, True)
    if pad_cache_to:
        caches = {k: attn_lib.pad_stacked_cache(c, pad_cache_to, cfg, T)
                  for k, c in caches.items()}
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, hidden[:, -1:], cfg)
    return logits, caches


def decode_step(params, cache, token, cfg):
    """token [B,1] int32 -> (logits [B,1,V], new cache)."""
    x = embed(params["embed"], token)

    def scan_dec(stack_params, stack_cache, x, moe_flag):
        def body(h, xs):
            lp, c = xs
            h2, c2 = block_decode(lp, h, cfg, c, moe=moe_flag)
            return h2, c2

        return maybe_scan(body, x, (stack_params, stack_cache))

    new_cache = {}
    if "dense_layers" in params:
        x, new_cache["dense"] = scan_dec(params["dense_layers"],
                                         cache["dense"], x, False)
    if "moe_layers" in params:
        x, new_cache["moe"] = scan_dec(params["moe_layers"],
                                       cache["moe"], x, True)
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_fn(params, hidden, cfg), new_cache
