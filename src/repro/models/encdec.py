"""Whisper-style encoder-decoder backbone (audio).

Per the assignment the conv frontend is a STUB: `input_specs()` provides
precomputed frame embeddings [B, encoder_seq, d] (what the two strided
convs would produce). The backbone is real: bidirectional encoder layers,
causal decoder layers with cross-attention into the encoder states.

Serving: `prefill` runs the encoder once and caches (decoder self KV,
cross KV); `decode_step` advances the decoder one token.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.common import ckpt, maybe_scan
from repro.models.common import (COMPUTE_DTYPE, cross_entropy, dense_init,
                                 embed, init_embedding, prepend_layers_axis,
                                 rms_norm, stack_init, unembed, zeros_init)
from repro.models.mlp import init_mlp, mlp_forward
from repro.sharding.rules import maybe_constrain


def init_cross_attn(key, cfg):
    # same projection structure as self-attention
    return attn_lib.init_gqa(key, cfg)


def cross_attn_forward(p, x, enc_kv, cfg):
    """x [B,T,d] queries; enc_kv = (k, v) [B,S,KV,hd] precomputed."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(COMPUTE_DTYPE))
    k, v = enc_kv
    s = attn_lib._grouped_scores(q, k)
    probs = jax.nn.softmax(s, axis=-1)
    out = attn_lib._grouped_out(probs, v)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))


def cross_kv(p, enc_states, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_states, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", enc_states, p["wv"].astype(COMPUTE_DTYPE))
    return k, v


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, aa = attn_lib.init_gqa(k1, cfg)
    mp, ma = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp)
    return (dict(ln1=zeros_init((cfg.d_model,)), attn=ap,
                 ln2=zeros_init((cfg.d_model,)), mlp=mp),
            dict(ln1=("embed",), attn=aa, ln2=("embed",), mlp=ma))


def enc_layer_forward(p, x, cfg, positions):
    """Bidirectional self-attention (no causal mask)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn_lib._qkv(p["attn"], h, cfg, positions[None, :])
    s = attn_lib._grouped_scores(q, k)
    out = attn_lib._grouped_out(jax.nn.softmax(s, axis=-1), v)
    y = jnp.einsum("bthk,hkd->btd", out, p["attn"]["wo"].astype(COMPUTE_DTYPE))
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h2, cfg.mlp)


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    sp, sa = attn_lib.init_gqa(k1, cfg)
    cp, ca = init_cross_attn(k2, cfg)
    mp, ma = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp)
    p = dict(ln1=zeros_init((cfg.d_model,)), self_attn=sp,
             ln_x=zeros_init((cfg.d_model,)), cross_attn=cp,
             ln2=zeros_init((cfg.d_model,)), mlp=mp)
    a = dict(ln1=("embed",), self_attn=sa, ln_x=("embed",), cross_attn=ca,
             ln2=("embed",), mlp=ma)
    return p, a


def dec_layer_forward(p, x, enc_kv, cfg, positions, q_chunk=512):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_lib.gqa_forward(p["self_attn"], h, cfg, positions,
                                 q_chunk=q_chunk)
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    x = x + cross_attn_forward(p["cross_attn"], h, enc_kv, cfg)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, cfg.mlp)


def dec_layer_decode(p, x, cache, enc_kv, cfg):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, cache = attn_lib.gqa_decode(p["self_attn"], h, cfg, cache)
    x = x + y
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    x = x + cross_attn_forward(p["cross_attn"], h, enc_kv, cfg)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, cfg.mlp), cache


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"], a["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model)
    p["enc_layers"], a["enc_layers"] = stack_init(
        lambda k: init_enc_layer(k, cfg), ks[1], cfg.encoder_layers)
    p["dec_layers"], a["dec_layers"] = stack_init(
        lambda k: init_dec_layer(k, cfg), ks[2], cfg.num_layers)
    p["enc_norm"], a["enc_norm"] = zeros_init((cfg.d_model,)), ("embed",)
    p["final_norm"], a["final_norm"] = zeros_init((cfg.d_model,)), ("embed",)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = init_embedding(ks[3], cfg.vocab_size,
                                                    cfg.d_model)
    return p, a


def encode(params, frames, cfg):
    """frames [B, S_enc, d] (stub conv-frontend output)."""
    x = frames.astype(COMPUTE_DTYPE)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(h, lp):
        f = ckpt(lambda q, hh: enc_layer_forward(q, hh, cfg,
                                                           positions))
        return f(lp, h), None

    x, _ = maybe_scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_logits(params, hidden, cfg):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden)


def loss_fn(params, batch, cfg, *, q_chunk: int = 512, **_):
    tokens, labels, frames = batch["tokens"], batch["labels"], batch["frames"]
    enc = encode(params, frames, cfg)
    x = embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(h, lp):
        kv = cross_kv(lp["cross_attn"], enc, cfg)
        f = ckpt(
            lambda q, hh: dec_layer_forward(q, hh, kv, cfg, positions,
                                            q_chunk=q_chunk))
        return f(lp, h), None

    x, _ = maybe_scan(body, x, params["dec_layers"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = cross_entropy(_dec_logits(params, hidden, cfg), labels)
    return ce, dict(ce=ce, aux=jnp.float32(0))


def init_cache(cfg, batch: int, max_seq: int):
    self_c, self_ax = attn_lib.init_gqa_cache(cfg, batch, max_seq)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers

    def stack(c):
        return jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v, (L,) + v.shape).copy(), c)

    cache = dict(
        self=stack(self_c),
        cross_k=jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), COMPUTE_DTYPE),
        cross_v=jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), COMPUTE_DTYPE),
    )
    axes = dict(self=prepend_layers_axis(self_ax),
                cross_k=("layers", "batch", None, "kv_heads", "head_dim"),
                cross_v=("layers", "batch", None, "kv_heads", "head_dim"))
    return cache, axes


def prefill(params, tokens, cfg, *, frames=None, q_chunk: int = 512,
            pad_cache_to=None, **_):
    """Encode frames, run the decoder over `tokens`, return caches."""
    B_, T = tokens.shape
    if frames is None:
        frames = jnp.zeros((B_, cfg.encoder_seq, cfg.d_model), COMPUTE_DTYPE)
    enc = encode(params, frames, cfg)
    x = embed(params["embed"], tokens)
    positions = jnp.arange(T, dtype=jnp.int32)
    idxT = jnp.full((B_,), T, jnp.int32)

    def body(h, lp):
        kv = cross_kv(lp["cross_attn"], enc, cfg)
        h2 = dec_layer_forward(lp, h, kv, cfg, positions, q_chunk=q_chunk)
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        _, sk, sv = attn_lib._qkv(lp["self_attn"], hn, cfg, positions[None, :])
        return h2, dict(self=dict(k=sk, v=sv, idx=idxT),
                        cross=kv)

    x, caches = maybe_scan(body, x, params["dec_layers"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    self_c = caches["self"]
    if pad_cache_to:
        self_c = attn_lib.pad_stacked_cache(self_c, pad_cache_to, cfg, T)
    cache = dict(self=self_c, cross_k=caches["cross"][0],
                 cross_v=caches["cross"][1])
    return _dec_logits(params, hidden[:, -1:], cfg), cache


def decode_step(params, cache, token, cfg):
    x = embed(params["embed"], token)

    def body(h, xs):
        lp, sc, ck, cv = xs
        h2, sc2 = dec_layer_decode(lp, h, sc, (ck, cv), cfg)
        return h2, sc2

    x, new_self = maybe_scan(
        body, x, (params["dec_layers"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = dict(self=new_self, cross_k=cache["cross_k"],
                     cross_v=cache["cross_v"])
    return _dec_logits(params, hidden, cfg), new_cache
