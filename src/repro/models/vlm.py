"""InternVL2-style VLM backbone (text decoder + stub vision frontend).

Per the assignment the InternViT frontend is a STUB: `input_specs()`
provides precomputed patch embeddings [B, num_image_tokens, d_model] which
are projected and prepended to the text embeddings. The LM backbone is the
standard decoder-only transformer (Qwen2-0.5B-family config); loss is
computed on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.common import maybe_scan
from repro.models.common import (COMPUTE_DTYPE, cross_entropy, dense_init,
                                 embed, rms_norm)


def init_params(cfg, key):
    k1, k2 = jax.random.split(key)
    p, a = tf.init_params(cfg, k1)
    # mlp projector from (stub) vision embedding space into the LM stream
    p["vision_proj"] = dense_init(k2, (cfg.d_model, cfg.d_model), cfg.d_model)
    a["vision_proj"] = ("embed", "embed_in")
    return p, a


def _prefix_inputs(params, batch, cfg):
    tokens = batch["tokens"]
    img = batch["img_embeds"].astype(COMPUTE_DTYPE)
    img = jnp.einsum("bnd,de->bne", img,
                     params["vision_proj"].astype(COMPUTE_DTYPE))
    x_txt = embed(params["embed"], tokens)
    return jnp.concatenate([img, x_txt], axis=1)


def loss_fn(params, batch, cfg, *, q_chunk: int = 512, **_):
    tokens, labels = batch["tokens"], batch["labels"]
    x = _prefix_inputs(params, batch, cfg)
    T_total = x.shape[1]
    n_img = T_total - tokens.shape[1]
    positions = jnp.arange(T_total, dtype=jnp.int32)
    hidden, aux = tf.forward_hidden(params, x, cfg, positions,
                                    q_chunk=q_chunk)
    logits = tf.logits_fn(params, hidden[:, n_img:], cfg)
    ce = cross_entropy(logits, labels)
    return ce + 0.01 * aux, dict(ce=ce, aux=aux)


def init_cache(cfg, batch: int, max_seq: int):
    # cache covers image prefix + text
    return tf.init_cache(cfg, batch, max_seq)


def prefill(params, tokens, cfg, *, img_embeds=None, q_chunk: int = 512,
            pad_cache_to=None, **_):
    """Image prefix + prompt prefill. Returns cache over the full prefix."""
    B_ = tokens.shape[0]
    if img_embeds is None:
        img_embeds = jnp.zeros((B_, cfg.num_image_tokens, cfg.d_model),
                               COMPUTE_DTYPE)
    x = _prefix_inputs(params, dict(tokens=tokens, img_embeds=img_embeds), cfg)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    idxT = jnp.full((B_,), T, jnp.int32)
    caches = {}

    def scan_fill(stack_params, h, moe_flag):
        def body(hh, lp):
            h2, _ = tf.block_forward(lp, hh, cfg, positions, moe=moe_flag,
                                     q_chunk=q_chunk)
            hn = rms_norm(hh, lp["ln1"], cfg.norm_eps)
            from repro.models import attention as attn_lib
            _, k, v = attn_lib._qkv(lp["attn"], hn, cfg, positions[None, :])
            return h2, dict(k=k, v=v, idx=idxT)

        return maybe_scan(body, h, stack_params)

    if "dense_layers" in params:
        x, caches["dense"] = scan_fill(params["dense_layers"], x, False)
    if "moe_layers" in params:
        x, caches["moe"] = scan_fill(params["moe_layers"], x, True)
    if pad_cache_to:
        from repro.models import attention as attn_lib
        caches = {k: attn_lib.pad_stacked_cache(c, pad_cache_to, cfg, T)
                  for k, c in caches.items()}
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return tf.logits_fn(params, hidden[:, -1:], cfg), caches


def decode_step(params, cache, token, cfg):
    return tf.decode_step(params, cache, token, cfg)
