"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (cfg.block_pattern, default 2:1): two recurrent blocks then one
local (sliding-window) MQA attention block. 38 layers = 12 full groups + 2
trailing recurrent blocks, kept in faithful order via two scans (grouped +
trailing).

RG-LRU (Griffin eq. 1-4):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = a^(c * r_t),  a = sigmoid(Lambda) (c = 8)
    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t)

Train/prefill evaluate the linear recurrence with an associative scan
(log-depth); decode is the O(1) update. The recurrent branch includes the
Griffin temporal conv (kernel 4) and GeGLU output gating.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.common import ckpt, maybe_scan
from repro.models.common import (COMPUTE_DTYPE, cross_entropy, dense_init,
                                 embed, init_embedding, prepend_layers_axis,
                                 rms_norm, stack_init, unembed, zeros_init)
from repro.models.mlp import init_mlp, mlp_forward
from repro.sharding.rules import maybe_constrain

C_GATE = 8.0


def _lru_width(cfg) -> int:
    return cfg.lru_width or cfg.d_model


def init_recurrent_block(key, cfg):
    d, w = cfg.d_model, _lru_width(cfg)
    ks = jax.random.split(key, 7)
    p = dict(
        ln=zeros_init((d,)),
        w_in=dense_init(ks[0], (d, w), d),       # recurrent branch input
        w_gate_in=dense_init(ks[1], (d, w), d),  # multiplicative branch
        conv_w=dense_init(ks[2], (cfg.conv_kernel, w), cfg.conv_kernel),
        conv_b=zeros_init((w,)),
        w_a=dense_init(ks[3], (w, w), w),
        b_a=zeros_init((w,)),
        w_x=dense_init(ks[4], (w, w), w),
        b_x=zeros_init((w,)),
        # Lambda init so a = sigmoid(Lambda) ~ U(0.9, 0.999)-ish
        lam=jnp.asarray(jax.random.uniform(ks[5], (w,), jnp.float32,
                                           2.2, 6.9)),
        w_out=dense_init(ks[6], (w, d), w),
        ln_mlp=zeros_init((d,)),
    )
    a = dict(ln=("embed",), w_in=("embed", "ffn"), w_gate_in=("embed", "ffn"),
             conv_w=(None, "ffn"), conv_b=("ffn",),
             w_a=("ffn", "ffn_in"), b_a=("ffn",),
             w_x=("ffn", "ffn_in"), b_x=("ffn",),
             lam=("ffn",), w_out=("ffn", "embed"), ln_mlp=("embed",))
    mp, ma = init_mlp(jax.random.fold_in(key, 7), d, cfg.d_ff, cfg.mlp)
    p["mlp"], a["mlp"] = mp, ma
    return p, a


def init_attn_block(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, aa = attn_lib.init_gqa(k1, cfg)
    mp, ma = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp)
    p = dict(ln=zeros_init((cfg.d_model,)), attn=ap,
             ln_mlp=zeros_init((cfg.d_model,)), mlp=mp)
    a = dict(ln=("embed",), attn=aa, ln_mlp=("embed",), mlp=ma)
    return p, a


def _rglru_scan(x_gated, a_pow, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative scan. Inputs fp32."""
    b = jnp.sqrt(jnp.maximum(1.0 - a_pow * a_pow, 1e-12)) * x_gated

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s, h = jax.lax.associative_scan(op, (a_pow, b), axis=1)
    if h0 is not None:
        # fold initial state: h_t += (prod a_{1..t}) * h0
        h = h + a_s * h0[:, None]
    return h


def _recurrent_branch(p, xw, cfg, conv_hist=None, h0=None):
    """xw [B,T,w] conv input. Returns (y, (new_conv_hist, h_last))."""
    B_, T, w = xw.shape
    k = cfg.conv_kernel
    if conv_hist is None:
        pad = jnp.zeros((B_, k - 1, w), xw.dtype)
    else:
        pad = conv_hist
    xp = jnp.concatenate([pad, xw], axis=1)
    conv = sum(xp[:, i:i + T] * p["conv_w"][i].astype(COMPUTE_DTYPE)
               for i in range(k)) + p["conv_b"].astype(COMPUTE_DTYPE)
    xc = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(xc @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xc @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -C_GATE * jax.nn.softplus(-p["lam"]) * r      # log a^(c*r)
    a_pow = jnp.exp(log_a)
    h = _rglru_scan(i * xc, a_pow, h0)
    new_hist = xp[:, -(k - 1):] if k > 1 else jnp.zeros((B_, 0, w), xw.dtype)
    return h.astype(COMPUTE_DTYPE), (new_hist, h[:, -1])


def recurrent_block_forward(p, x, cfg, *, want_state: bool = False):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xw = jnp.einsum("btd,dw->btw", h, p["w_in"].astype(COMPUTE_DTYPE))
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", h,
                                  p["w_gate_in"].astype(COMPUTE_DTYPE)))
    y, state = _recurrent_branch(p, xw, cfg)
    y = y * gate
    x = x + jnp.einsum("btw,wd->btd", y, p["w_out"].astype(COMPUTE_DTYPE))
    x = maybe_constrain(x, ("batch", "seq", "embed"))
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + mlp_forward(p["mlp"], h2, cfg.mlp)
    if want_state:
        return x, state
    return x, jnp.float32(0)


def recurrent_block_decode(p, x, cfg, cache):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xw = jnp.einsum("btd,dw->btw", h, p["w_in"].astype(COMPUTE_DTYPE))
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", h,
                                  p["w_gate_in"].astype(COMPUTE_DTYPE)))
    y, (new_hist, h_last) = _recurrent_branch(
        p, xw, cfg, conv_hist=cache["conv"], h0=cache["h"])
    y = y * gate
    x = x + jnp.einsum("btw,wd->btd", y, p["w_out"].astype(COMPUTE_DTYPE))
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + mlp_forward(p["mlp"], h2, cfg.mlp)
    return x, dict(conv=new_hist, h=h_last, idx=cache["idx"] + 1)


def attn_block_forward(p, x, cfg, positions, *, want_kv: bool = False,
                       q_chunk: int = 512):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y = attn_lib.gqa_forward(p["attn"], h, cfg, positions, q_chunk=q_chunk)
    x = x + y
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + mlp_forward(p["mlp"], h2, cfg.mlp)
    if want_kv:
        _, k, v = attn_lib._qkv(p["attn"], h, cfg,
                                positions[None, :])
        w = cfg.local_window
        if k.shape[1] > w:
            k, v = k[:, -w:], v[:, -w:]
        return x, (k, v)
    return x, jnp.float32(0)


def attn_block_decode(p, x, cfg, cache):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, cache = attn_lib.gqa_decode(p["attn"], h, cfg, cache)
    x = x + y
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + mlp_forward(p["mlp"], h2, cfg.mlp)
    return x, cache


# ---------------------------------------------------------------------------
# model API: groups of (pattern) + trailing recurrent blocks
# ---------------------------------------------------------------------------

def _group_layout(cfg) -> Tuple[int, int]:
    period = len(cfg.block_pattern)          # e.g. 3 = (rglru, rglru, local)
    n_groups = cfg.num_layers // period
    trailing = cfg.num_layers - n_groups * period  # trailing rglru blocks
    return n_groups, trailing


def _attn_cfg(cfg):
    """Local-attention blocks use the sliding window."""
    import dataclasses
    return dataclasses.replace(cfg, sliding_window=cfg.local_window)


def init_group(key, cfg):
    """One pattern group: stacked recurrent blocks + one attention block."""
    n_rec = sum(1 for b in cfg.block_pattern if b == "rglru")
    k1, k2 = jax.random.split(key)
    rp, ra = stack_init(lambda k: init_recurrent_block(k, cfg), k1, n_rec)
    ap, aa = init_attn_block(k2, _attn_cfg(cfg))
    return dict(rec=rp, attn=ap), dict(rec=ra, attn=aa)


def init_params(cfg, key):
    n_groups, trailing = _group_layout(cfg)
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"], a["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model)
    p["groups"], a["groups"] = stack_init(lambda k: init_group(k, cfg),
                                          ks[1], n_groups)
    if trailing:
        p["trailing"], a["trailing"] = stack_init(
            lambda k: init_recurrent_block(k, cfg), ks[2], trailing)
    p["final_norm"], a["final_norm"] = zeros_init((cfg.d_model,)), ("embed",)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = init_embedding(ks[3], cfg.vocab_size,
                                                    cfg.d_model)
    return p, a


def _group_forward(gp, x, cfg, positions, q_chunk=512):
    def rec_body(h, lp):
        f = ckpt(lambda q, hh: recurrent_block_forward(q, hh, cfg))
        h2, _ = f(lp, h)
        return h2, None

    x, _ = maybe_scan(rec_body, x, gp["rec"])
    f = ckpt(lambda q, hh: attn_block_forward(
        q, hh, _attn_cfg(cfg), positions, q_chunk=q_chunk))
    x, _ = f(gp["attn"], x)
    return x


def loss_fn(params, batch, cfg, *, q_chunk: int = 512, **_):
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(h, gp):
        return _group_forward(gp, h, cfg, positions, q_chunk), None

    x, _ = maybe_scan(body, x, params["groups"])
    if "trailing" in params:
        def tbody(h, lp):
            f = ckpt(
                lambda q, hh: recurrent_block_forward(q, hh, cfg))
            h2, _ = f(lp, h)
            return h2, None

        x, _ = maybe_scan(tbody, x, params["trailing"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    ce = cross_entropy(unembed(table, hidden), labels)
    return ce, dict(ce=ce, aux=jnp.float32(0))


def init_cache(cfg, batch: int, max_seq: int):
    n_groups, trailing = _group_layout(cfg)
    w = _lru_width(cfg)
    n_rec = sum(1 for b in cfg.block_pattern if b == "rglru")
    k = cfg.conv_kernel
    attn_c, attn_ax = attn_lib.init_gqa_cache(_attn_cfg(cfg), batch, max_seq)
    rec_c = dict(conv=jnp.zeros((batch, k - 1, w), COMPUTE_DTYPE),
                 h=jnp.zeros((batch, w), jnp.float32),
                 idx=jnp.zeros((batch,), jnp.int32))
    rec_ax = dict(conv=("batch", None, "ffn"), h=("batch", "ffn"),
                  idx=("batch",))

    def stack(c, n):
        return jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v, (n,) + v.shape).copy(), c)

    cache = dict(groups=dict(rec=stack(stack(rec_c, n_rec), n_groups),
                             attn=stack(attn_c, n_groups)))
    axes = dict(groups=dict(
        rec=prepend_layers_axis(prepend_layers_axis(rec_ax)),
        attn=prepend_layers_axis(attn_ax)))
    if trailing:
        cache["trailing"] = stack(rec_c, trailing)
        axes["trailing"] = prepend_layers_axis(rec_ax)
    return cache, axes


def prefill(params, tokens, cfg, *, q_chunk: int = 512,
            pad_cache_to=None, **_):
    B_, T = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.arange(T, dtype=jnp.int32)
    idxT = jnp.full((B_,), T, jnp.int32)

    def group_body(h, gp):
        def rec_body(hh, lp):
            h2, (conv_s, h_last) = recurrent_block_forward(
                lp, hh, cfg, want_state=True)
            return h2, dict(conv=conv_s, h=h_last, idx=idxT)

        h, rec_cache = maybe_scan(rec_body, h, gp["rec"])
        h, (kc, vc) = attn_block_forward(gp["attn"], h, _attn_cfg(cfg),
                                         positions, want_kv=True,
                                         q_chunk=q_chunk)
        return h, dict(rec=rec_cache, attn=dict(k=kc, v=vc, idx=idxT))

    x, gcache = maybe_scan(group_body, x, params["groups"])
    if pad_cache_to:
        gcache = dict(gcache, attn=attn_lib.pad_stacked_cache(
            gcache["attn"], pad_cache_to, _attn_cfg(cfg), T))
    cache = dict(groups=gcache)
    if "trailing" in params:
        def tbody(hh, lp):
            h2, (conv_s, h_last) = recurrent_block_forward(
                lp, hh, cfg, want_state=True)
            return h2, dict(conv=conv_s, h=h_last, idx=idxT)

        x, cache["trailing"] = maybe_scan(tbody, x, params["trailing"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden[:, -1:]), cache


def decode_step(params, cache, token, cfg):
    x = embed(params["embed"], token)

    def group_body(h, xs):
        gp, gc = xs

        def rec_body(hh, rxs):
            lp, rc = rxs
            h2, rc2 = recurrent_block_decode(lp, hh, cfg, rc)
            return h2, rc2

        h, rec_c = maybe_scan(rec_body, h, (gp["rec"], gc["rec"]))
        h, attn_c = attn_block_decode(gp["attn"], h, _attn_cfg(cfg),
                                      gc["attn"])
        return h, dict(rec=rec_c, attn=attn_c)

    x, gcache = maybe_scan(group_body, x, (params["groups"],
                                             cache["groups"]))
    new_cache = dict(groups=gcache)
    if "trailing" in params:
        def tbody(hh, xs):
            lp, rc = xs
            h2, rc2 = recurrent_block_decode(lp, hh, cfg, rc)
            return h2, rc2

        x, new_cache["trailing"] = maybe_scan(
            tbody, x, (params["trailing"], cache["trailing"]))
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden), new_cache
