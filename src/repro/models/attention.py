"""Attention: GQA (+bias, +qk-norm, +sliding-window) and MLA (DeepSeek-V2).

Memory discipline:
  * train/prefill run *blockwise over query chunks* (scores never exceed
    [B, KV, G, q_chunk, S] per step — flash-style, exact softmax since the
    full key axis is resident per chunk);
  * decode is a single-step attention over the cache; MLA decode uses the
    absorbed form (scores against the compressed c_kv latent — the cache is
    never decompressed, which is what makes 32k×128-batch decode fit).

KV caches are laid out [B, S_max, ...] with logical axes
("batch", "cache_seq", ...) — cache_seq is sharded over the model axis at
decode shapes (flash-decoding split-KV; GSPMD inserts the softmax combine).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import maybe_scan
from repro.models.common import (COMPUTE_DTYPE, PARAM_DTYPE, apply_rope,
                                 dense_init, ones_init, rms_norm,
                                 rope_tables, zeros_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _padded_heads(cfg) -> int:
    return max(cfg.pad_q_heads_to or 0, cfg.num_heads)


def _head_mask(cfg):
    """[Hp] 1/0 mask; padded heads are zeroed before the out projection so
    they neither contribute output nor receive gradients (exactness)."""
    Hp, H = _padded_heads(cfg), cfg.num_heads
    if Hp == H:
        return None
    return (jnp.arange(Hp) < H).astype(COMPUTE_DTYPE)


def init_gqa(key, cfg):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    Hp = _padded_heads(cfg)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)

    def padh(w):  # zero-init padded head slots
        return jnp.zeros((d, Hp, hd), w.dtype).at[:, :H].set(w) \
            if Hp != H else w

    p = dict(
        wq=padh(dense_init(ks[0], (d, H, hd), d)),
        wk=dense_init(ks[1], (d, KV, hd), d),
        wv=dense_init(ks[2], (d, KV, hd), d),
        wo=(jnp.zeros((Hp, hd, d), PARAM_DTYPE)
            .at[:H].set(dense_init(ks[3], (H, hd, d), H * hd))
            if Hp != H else dense_init(ks[3], (H, hd, d), H * hd)),
    )
    a = dict(
        wq=("embed", "q_heads", "head_dim"),
        wk=("embed", "kv_heads", "head_dim"),
        wv=("embed", "kv_heads", "head_dim"),
        wo=("q_heads", "head_dim", "embed"),
    )
    if cfg.qkv_bias:
        p |= dict(bq=zeros_init((Hp, hd)), bk=zeros_init((KV, hd)),
                  bv=zeros_init((KV, hd)))
        a |= dict(bq=("q_heads", "head_dim"), bk=("kv_heads", "head_dim"),
                  bv=("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p |= dict(q_norm=zeros_init((hd,)), k_norm=zeros_init((hd,)))
        a |= dict(q_norm=("head_dim",), k_norm=("head_dim",))
    return p, a


def _qkv(p, x, cfg, positions):
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(COMPUTE_DTYPE))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(COMPUTE_DTYPE)
        k = k + p["bk"].astype(COMPUTE_DTYPE)
        v = v + p["bv"].astype(COMPUTE_DTYPE)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _grouped_scores(q, k):
    """q [B,T,H,hd], k [B,S,KV,hd] -> scores [B,KV,G,T,S] fp32."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                   preferred_element_type=jnp.float32)
    return s / math.sqrt(hd)


def _grouped_out(probs, v):
    """probs [B,KV,G,T,S] fp32, v [B,S,KV,hd] -> [B,T,H,hd]."""
    B, KV, G, T, S = probs.shape
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(COMPUTE_DTYPE), v)
    return out.reshape(B, T, KV * G, v.shape[-1])


def _causal_mask(q_pos, k_pos, window: Optional[int]):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def gqa_forward(p, x, cfg, positions, *, q_chunk: int = 512):
    """Full-sequence causal attention, blockwise over query chunks.

    positions: [T] int32 (shared across the batch; no packing).
    """
    B, T, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions[None, :])
    n_chunks = T // q_chunk if (q_chunk < T and T % q_chunk == 0) else 1
    qc = T // n_chunks
    q_chunks = jnp.moveaxis(q.reshape(B, n_chunks, qc, *q.shape[2:]), 1, 0)
    p_chunks = positions.reshape(n_chunks, qc)

    def chunk_fn(carry, inp):
        qi, qpi = inp  # [B, qc, H, hd], [qc]
        s = _grouped_scores(qi, k)  # [B,KV,G,qc,S]
        mask = _causal_mask(qpi, positions, cfg.sliding_window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        return carry, _grouped_out(probs, v)

    _, outs = maybe_scan(chunk_fn, None, (q_chunks, p_chunks))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, q.shape[2], q.shape[3])
    mask_h = _head_mask(cfg)
    if mask_h is not None:
        out = out * mask_h[None, None, :, None]
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))


def pad_stacked_cache(cache: dict, max_seq: int, cfg, prompt_len: int) -> dict:
    """Grow a prefill-built stacked cache ([L, B, S, ...]) to decode
    capacity `max_seq` along the sequence axis (axis=2).

    Sliding-window caches are ring buffers of size `window`; instead of
    padding they are rolled so the ring invariant slot == token % window
    holds for subsequent decode steps."""
    def pad(x):
        return jnp.pad(x, [(0, 0), (0, 0), (0, max_seq - x.shape[2])] +
                       [(0, 0)] * (x.ndim - 3))

    if "k" in cache:  # GQA
        S = cache["k"].shape[2]
        if cfg.sliding_window:
            # ring buffer of size min(window, max_seq); invariant:
            # slot == token % size
            target = min(cfg.sliding_window, max_seq)
            if S == target and prompt_len >= target:
                shift = prompt_len % target
                return dict(cache, k=jnp.roll(cache["k"], shift, axis=2),
                            v=jnp.roll(cache["v"], shift, axis=2))
            if S < target:
                def padw(x):
                    return jnp.pad(x, [(0, 0), (0, 0), (0, target - S)] +
                                   [(0, 0)] * (x.ndim - 3))
                return dict(cache, k=padw(cache["k"]), v=padw(cache["v"]))
            return cache
        if S < max_seq:
            return dict(cache, k=pad(cache["k"]), v=pad(cache["v"]))
        return cache
    # MLA
    if cache["c_kv"].shape[2] < max_seq:
        return dict(cache, c_kv=pad(cache["c_kv"]),
                    k_rope=pad(cache["k_rope"]))
    return cache


def init_gqa_cache(cfg, batch: int, max_seq: int):
    """idx is a per-sequence position vector [B] — decode slots advance
    independently (continuous batching admits requests at any time)."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    seq = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    c = dict(
        k=jnp.zeros((batch, seq, KV, hd), COMPUTE_DTYPE),
        v=jnp.zeros((batch, seq, KV, hd), COMPUTE_DTYPE),
        idx=jnp.zeros((batch,), jnp.int32),
    )
    a = dict(k=("batch", "cache_seq", "kv_heads", "head_dim"),
             v=("batch", "cache_seq", "kv_heads", "head_dim"),
             idx=("batch",))
    return c, a


def gqa_decode(p, x, cfg, cache):
    """One-token decode. x [B,1,d]. Sliding-window caches are ring buffers;
    per-sequence positions cache['idx'] [B]."""
    B = x.shape[0]
    idx = cache["idx"]                          # [B]
    positions = idx[:, None]
    q, k, v = _qkv(p, x, cfg, positions)
    S = cache["k"].shape[1]
    slot = idx % S if cfg.sliding_window else jnp.minimum(idx, S - 1)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    s = _grouped_scores(q, k_cache)  # [B,KV,G,1,S]
    kpos = jnp.arange(S)
    if cfg.sliding_window:
        # ring buffer: valid slots are the last min(idx+1, S) writes
        age = (slot[:, None] - kpos[None, :]) % S
        valid = age < jnp.minimum(idx + 1, S)[:, None]
    else:
        valid = kpos[None, :] <= idx[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = _grouped_out(probs, v_cache)
    mask_h = _head_mask(cfg)
    if mask_h is not None:
        out = out * mask_h[None, None, :, None]
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))
    return y, dict(k=k_cache, v=v_cache, idx=idx + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    p = dict(
        wq_a=dense_init(ks[0], (d, qlr), d),
        q_norm=zeros_init((qlr,)),
        wq_b=dense_init(ks[1], (qlr, H, nope + rope_d), qlr),
        wkv_a=dense_init(ks[2], (d, kvlr + rope_d), d),
        kv_norm=zeros_init((kvlr,)),
        wkv_b_k=dense_init(ks[3], (kvlr, H, nope), kvlr),
        wkv_b_v=dense_init(ks[4], (kvlr, H, vh), kvlr),
        wo=dense_init(ks[5], (H, vh, d), H * vh),
    )
    a = dict(
        wq_a=("embed", "q_lora"), q_norm=("q_lora",),
        wq_b=("q_lora", "q_heads", "head_dim"),
        wkv_a=("embed", "kv_lora"), kv_norm=("kv_lora",),
        wkv_b_k=("kv_lora", "q_heads", "head_dim"),
        wkv_b_v=("kv_lora", "q_heads", "head_dim"),
        wo=("q_heads", "head_dim", "embed"),
    )
    return p, a


def _mla_q(p, x, cfg, positions):
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_lat = rms_norm(jnp.einsum("btd,dr->btr", x, p["wq_a"].astype(COMPUTE_DTYPE)),
                     p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", q_lat, p["wq_b"].astype(COMPUTE_DTYPE))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    return q_nope, apply_rope(q_rope, cos, sin)


def _mla_kv_latent(p, x, cfg, positions):
    kvlr, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("btd,dr->btr", x, p["wkv_a"].astype(COMPUTE_DTYPE))
    c_kv = rms_norm(kv[..., :kvlr], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., None, kvlr:]  # [B,T,1,rope_d] shared across heads
    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    return c_kv, apply_rope(k_rope, cos, sin)[..., 0, :]


def mla_forward(p, x, cfg, positions, *, q_chunk: int = 512):
    """Train/prefill MLA: decompress keys per query chunk (exact)."""
    B, T, _ = x.shape
    nope, vh = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions[None, :])
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions[None, :])
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wkv_b_k"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["wkv_b_v"].astype(COMPUTE_DTYPE))
    scale = 1.0 / math.sqrt(nope + cfg.qk_rope_head_dim)
    n_chunks = T // q_chunk if (q_chunk < T and T % q_chunk == 0) else 1
    qn = jnp.moveaxis(q_nope.reshape(B, n_chunks, -1, *q_nope.shape[2:]), 1, 0)
    qr = jnp.moveaxis(q_rope.reshape(B, n_chunks, -1, *q_rope.shape[2:]), 1, 0)
    qpos = positions.reshape(n_chunks, -1)

    def chunk_fn(_, inp):
        qni, qri, qpi = inp
        s = (jnp.einsum("bthk,bshk->bhts", qni, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bthk,bsk->bhts", qri, k_rope,
                          preferred_element_type=jnp.float32)) * scale
        mask = _causal_mask(qpi, positions, None)
        s = jnp.where(mask[None, None], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
        return None, jnp.einsum("bhts,bshk->bthk", probs, v)

    _, outs = maybe_scan(chunk_fn, None, (qn, qr, qpos))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, cfg.num_heads, vh)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))


def init_mla_cache(cfg, batch: int, max_seq: int):
    c = dict(
        c_kv=jnp.zeros((batch, max_seq, cfg.kv_lora_rank), COMPUTE_DTYPE),
        k_rope=jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), COMPUTE_DTYPE),
        idx=jnp.zeros((batch,), jnp.int32),
    )
    a = dict(c_kv=("batch", "cache_seq", "kv_lora"),
             k_rope=("batch", "cache_seq", "head_dim"), idx=("batch",))
    return c, a


def mla_decode(p, x, cfg, cache):
    """Absorbed-form decode: attention runs against the compressed latent."""
    B = x.shape[0]
    idx = cache["idx"]                                 # [B]
    positions = idx[:, None]
    nope, vh = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions)      # [B,1,H,*]
    c_new, kr_new = _mla_kv_latent(p, x, cfg, positions)
    S = cache["c_kv"].shape[1]
    bidx = jnp.arange(B)
    slot = jnp.minimum(idx, S - 1)
    c_kv = cache["c_kv"].at[bidx, slot].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, slot].set(kr_new[:, 0])
    # absorb W^UK into q: q_c [B,1,H,kv_lora]
    q_c = jnp.einsum("bthk,rhk->bthr", q_nope, p["wkv_b_k"].astype(COMPUTE_DTYPE))
    scale = 1.0 / math.sqrt(nope + cfg.qk_rope_head_dim)
    s = (jnp.einsum("bthr,bsr->bhts", q_c, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] <= idx[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DTYPE)
    # attend in latent space then decompress: out_lat [B,1,H? no—]
    out_lat = jnp.einsum("bhts,bsr->bthr", probs, c_kv)   # [B,1,H,kv_lora]
    out = jnp.einsum("bthr,rhk->bthk", out_lat, p["wkv_b_v"].astype(COMPUTE_DTYPE))
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))
    return y, dict(c_kv=c_kv, k_rope=k_rope, idx=idx + 1)
