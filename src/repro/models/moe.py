"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

Dispatch is sort-free gather/scatter (Megablocks-flavored, adapted to static
TPU shapes): assignments are ranked within their expert via an argsort-based
run-rank, tokens beyond an expert's capacity are dropped (counted), expert
buffers are [E, C, d] with the expert axis sharded over the model mesh axis
(expert parallelism). A Switch-style load-balance aux loss is returned.

DeepSeek-style shared experts run as a dense MLP on every token and are
added to the routed output.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import COMPUTE_DTYPE, dense_init
from repro.models.mlp import init_mlp, mlp_forward
from repro.sharding.rules import maybe_constrain


def _rank_within(ids: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its equal-value group (stable order)."""
    N = ids.shape[0]
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    idx = jnp.arange(N)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_ids[1:] != sorted_ids[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    return jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def init_moe(key, cfg):
    d = cfg.d_model
    d_ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    gated = cfg.mlp in ("swiglu", "geglu")
    ks = jax.random.split(key, 5)
    p = dict(
        router=dense_init(ks[0], (d, E), d, dtype=jnp.float32),
        w_up=dense_init(ks[1], (E, d, d_ff), d),
        w_down=dense_init(ks[2], (E, d_ff, d), d_ff),
    )
    # expert-FFN tensor parallelism: every expert's hidden dim sharded over
    # the model axis, d_model dim over data (FSDP at rest). Tokens then stay
    # data-local and the only per-layer collective is the dense-TP-style
    # psum of the combined output (see moe_forward_sharded / §Perf).
    a = dict(
        router=(None, "experts_router"),  # small; replicated
        w_up=(None, "embed", "ffn"),
        w_down=(None, "ffn", "embed"),
    )
    if gated:
        p["w_gate"] = dense_init(ks[3], (E, d, d_ff), d)
        a["w_gate"] = (None, "embed", "ffn")
    if cfg.num_shared_experts:
        sp, sa = init_mlp(ks[4], d, d_ff * cfg.num_shared_experts, cfg.mlp)
        p["shared"] = sp
        a["shared"] = sa
    return p, a


def capacity_for(cfg, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.num_experts_per_tok * cfg.capacity_factor
                      / cfg.num_experts))
    # large capacities round to 512 so the C dim divides the data(+pod)
    # mesh axes (the buffers shard [E->model, C->data]); small (smoke-test)
    # capacities stay fine-grained and simply replicate
    mult = 512 if c > 4096 else 8
    return max(8, -(-c // mult) * mult)


def _dispatch_compute_combine(xf, logits, w_gate, w_up, w_down, cfg,
                              f_slice_partial: bool):
    """Shared dispatch/compute/combine on *local* (or global) tokens.

    xf [N, d]; expert weights [E, d, f_loc] / [E, f_loc, d] — when
    `f_slice_partial`, f_loc is a TP slice and the returned out is a
    partial sum awaiting a psum over the model axis.
    Returns (out [N, d] fp32, load [E], importance [E]).
    """
    N, d = xf.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = capacity_for(cfg, N)
    gates = jax.nn.softmax(logits, axis=-1)                      # [N, E]
    weights, experts = jax.lax.top_k(gates, k)                   # [N, k]
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    load = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0) \
        / (N * k)
    importance = jnp.mean(gates, axis=0)

    flat_e = experts.reshape(-1).astype(jnp.int32)               # [N*k]
    rank = _rank_within(flat_e)
    keep = rank < C
    token_of = jnp.tile(jnp.arange(N, dtype=jnp.int32)[:, None],
                        (1, k)).reshape(-1)
    slot_e = jnp.where(keep, flat_e, E)
    buf_tok = (jnp.full((E * C,), -1, jnp.int32)
               .at[slot_e * C + rank].set(jnp.where(keep, token_of, -1),
                                          mode="drop")
               .reshape(E, C))
    x_e = jnp.where((buf_tok >= 0)[..., None],
                    xf[jnp.clip(buf_tok, 0, N - 1)], 0).astype(COMPUTE_DTYPE)

    up = jnp.einsum("ecd,edf->ecf", x_e, w_up.astype(COMPUTE_DTYPE))
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", x_e, w_gate.astype(COMPUTE_DTYPE))
        h = (jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g)) * up
    elif cfg.mlp == "squared_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    y_e = jnp.einsum("ecf,efd->ecd", h, w_down.astype(COMPUTE_DTYPE))

    gathered = y_e.reshape(E * C, d)[jnp.clip(flat_e * C + rank, 0,
                                              E * C - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered.astype(jnp.float32) * weights.reshape(-1)[:, None]
    out = jnp.zeros((N, d), jnp.float32).at[token_of].add(contrib)
    return out, load, importance


def moe_forward_sharded(p, x, cfg, rules) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map MoE: tokens stay data-local, expert FFNs are hidden-dim
    tensor-parallel over the model axis, so the only cross-device traffic
    is one output psum over 'model' per layer (dense-TP profile) plus the
    FSDP weight all-gather over 'data'. Replaces the GSPMD gather-based
    dispatch whose cross-data gathers lowered to per-layer all-gathers of
    the entire token buffer (measured 16x FLOP redundancy or 8x collective
    blowup — §Perf dbrx hillclimb)."""
    from repro.core.routing import shard_map as _shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    B, T, d = x.shape
    gated = "w_gate" in p

    def local_fn(xl, router, *ws):
        # xl [B_loc, T, d]; ws are (E, d/dp, f/tp)-local slices: gather d
        gat = lambda w, ax: jax.lax.all_gather(w, dp_axes, axis=ax,
                                               tiled=True)
        if gated:
            w_gate_f, w_up_f = gat(ws[0], 1), gat(ws[1], 1)
            w_down_f = gat(ws[2], 2)
        else:
            w_gate_f, w_up_f, w_down_f = None, gat(ws[0], 1), gat(ws[1], 2)
        Bl, Tl, _ = xl.shape
        xf = xl.reshape(Bl * Tl, d)
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
        out, load, imp = _dispatch_compute_combine(
            xf, logits, w_gate_f, w_up_f, w_down_f, cfg,
            f_slice_partial=True)
        out = jax.lax.psum(out, "model")          # partial over f slices
        load = jax.lax.pmean(load, dp_axes)
        imp = jax.lax.pmean(imp, dp_axes)
        aux = cfg.num_experts * jnp.sum(load * imp)
        return out.astype(xl.dtype).reshape(Bl, Tl, d), aux

    up_spec = rules.spec((None, "embed", "ffn"), p["w_up"].shape)
    down_spec = rules.spec((None, "ffn", "embed"), p["w_down"].shape)
    if gated:
        w_args = (p["w_gate"], p["w_up"], p["w_down"])
        w_specs = (up_spec, up_spec, down_spec)
    else:
        w_args = (p["w_up"], p["w_down"])
        w_specs = (up_spec, down_spec)
    fn = _shard_map(local_fn, mesh=mesh,
                    in_specs=(P(dp_spec, None, None), P(None, None))
                    + w_specs,
                    out_specs=(P(dp_spec, None, None), P()))
    out, aux = fn(x, p["router"], *w_args)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg.mlp)
    return out, aux


def moe_forward(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,T,d] -> (out [B,T,d], aux_loss scalar). Uses the shard_map
    data-local path when sharding rules are active and the batch divides
    the data axes; otherwise the single-device gather path."""
    from repro.sharding.rules import current_rules

    rules = current_rules()
    if rules is not None and "model" in rules.mesh.shape:
        dp_axes = tuple(a for a in ("pod", "data") if a in rules.mesh.shape)
        dp = rules._axis_size(dp_axes)
        if x.shape[0] % dp == 0:
            return moe_forward_sharded(p, x, cfg, rules)

    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    out, load, imp = _dispatch_compute_combine(
        xf, logits, p.get("w_gate"), p["w_up"], p["w_down"], cfg,
        f_slice_partial=False)
    aux = cfg.num_experts * jnp.sum(load * imp)
    out = out.astype(x.dtype).reshape(B, T, d)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg.mlp)
    return out, aux
