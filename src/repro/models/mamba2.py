"""Mamba-2 (SSD — state-space duality) language model.

Train/prefill use the chunked dual form (block-quadratic intra-chunk +
linear inter-chunk state passing, chunk = cfg.ssm_chunk); decode is the O(1)
recurrent update on a [B, H, P, N] state. Attention-free: the long_500k
decode shape runs with constant memory.

Layout: d_inner = expand*d_model, H = d_inner/headdim heads, shared B/C
(ngroups=1), depthwise causal conv (kernel 4) over [x, B, C].
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ckpt, maybe_scan
from repro.models.common import (COMPUTE_DTYPE, cross_entropy, dense_init,
                                 embed, init_embedding, prepend_layers_axis,
                                 rms_norm, stack_init, unembed, zeros_init)
from repro.sharding.rules import maybe_constrain


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return d_in, H, N, conv_dim


def init_block(key, cfg):
    d = cfg.d_model
    d_in, H, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    p = dict(
        ln=zeros_init((d,)),
        in_proj=dense_init(ks[0], (d, d_in + conv_dim + H), d),
        conv_w=dense_init(ks[1], (cfg.conv_kernel, conv_dim), cfg.conv_kernel),
        conv_b=zeros_init((conv_dim,)),
        A_log=jnp.zeros((H,), jnp.float32),
        dt_bias=jnp.zeros((H,), jnp.float32),
        D=jnp.ones((H,), jnp.float32),
        norm=zeros_init((d_in,)),
        out_proj=dense_init(ks[2], (d_in, d), d_in),
    )
    a = dict(
        ln=("embed",),
        in_proj=("embed", "ffn"),
        conv_w=(None, "ffn"), conv_b=("ffn",),
        A_log=("q_heads",), dt_bias=("q_heads",), D=("q_heads",),
        norm=("ffn",),
        out_proj=("ffn", "embed"),
    )
    return p, a


def _segsum(x):
    """x [..., T] -> lower-triangular pairwise cumulative sums [..., T, T]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dtA, B, C, chunk: int, init_state=None):
    """SSD dual form.

    x [b,l,h,p] (already dt-scaled), dtA [b,l,h], B/C [b,l,n].
    Returns (y [b,l,h,p], final_state [b,h,p,n]).
    """
    b, l, h, pdim = x.shape
    n = B.shape[-1]
    c = l // chunk
    xr = x.reshape(b, c, chunk, h, pdim)
    Ar = dtA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # [b,h,c,Q]
    Br = B.reshape(b, c, chunk, n)
    Cr = C.reshape(b, c, chunk, n)
    A_cs = jnp.cumsum(Ar, axis=-1)

    # 1) intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(Ar))                                   # [b,h,c,Q,Q]
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cr, Br, L.astype(jnp.float32), xr)

    # 2) chunk-final states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)              # [b,h,c,Q]
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Br, decay_states, xr)

    # 3) inter-chunk recurrence over chunk axis
    if init_state is None:
        init_state = jnp.zeros((b, h, pdim, n), states.dtype)
    chunk_decay = jnp.exp(A_cs[..., -1])                       # [b,h,c]

    def scan_fn(carry, inp):
        s_c, d_c = inp                                         # [b,h,p,n], [b,h]
        new = carry * d_c[..., None, None] + s_c
        return new, carry  # emit state *entering* this chunk

    final, prev_states = maybe_scan(
        scan_fn, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [b,c,h,p,n]

    # 4) state -> output within chunk
    state_decay = jnp.exp(A_cs)                                # [b,h,c,Q]
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cr, prev_states, state_decay)
    y = (Y_diag + Y_off).reshape(b, l, h, pdim)
    return y, final


def block_forward(p, x, cfg, *, want_state: bool = False):
    """x [B,T,d] -> (out, (conv_state, ssm_state) if want_state)."""
    B_, T, d = x.shape
    d_in, H, N, conv_dim = _dims(cfg)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", h, p["in_proj"].astype(COMPUTE_DTYPE))
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:].astype(jnp.float32)

    # depthwise causal conv
    k = cfg.conv_kernel
    xBC_pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xBC_pad[:, i:i + T] * p["conv_w"][i].astype(COMPUTE_DTYPE)
               for i in range(k)) + p["conv_b"].astype(COMPUTE_DTYPE)
    xBC_c = jax.nn.silu(conv)

    xs = xBC_c[..., :d_in].reshape(B_, T, H, cfg.ssm_headdim)
    Bm = xBC_c[..., d_in:d_in + N].astype(jnp.float32)
    Cm = xBC_c[..., d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                   # [H]
    x_dt = xs.astype(jnp.float32) * dt[..., None]
    # pad T to a chunk multiple: zero inputs with dtA=0 (decay 1) are
    # exact no-ops on the state and contribute nothing to y
    chunk = min(cfg.ssm_chunk, T)
    T_pad = -(-T // chunk) * chunk
    if T_pad != T:
        padt = [(0, 0), (0, T_pad - T)]
        x_dt = jnp.pad(x_dt, padt + [(0, 0), (0, 0)])
        dtA_p = jnp.pad(dt * A, padt + [(0, 0)])
        Bm_p = jnp.pad(Bm, padt + [(0, 0)])
        Cm_p = jnp.pad(Cm, padt + [(0, 0)])
    else:
        dtA_p, Bm_p, Cm_p = dt * A, Bm, Cm
    y, final_state = ssd_chunked(x_dt, dtA_p, Bm_p, Cm_p, chunk)
    y = y[:, :T]
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, d_in).astype(COMPUTE_DTYPE)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(COMPUTE_DTYPE))
    out = maybe_constrain(out, ("batch", "seq", "embed"))
    if want_state:
        conv_state = xBC_pad[:, -(k - 1):] if k > 1 else \
            jnp.zeros((B_, 0, conv_dim), xBC.dtype)
        return out, (conv_state, final_state)
    return out, jnp.float32(0)


def block_decode(p, x, cfg, cache):
    """One-token recurrent update. cache = dict(conv, ssm, idx)."""
    B_, _, d = x.shape
    d_in, H, N, conv_dim = _dims(cfg)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", h, p["in_proj"].astype(COMPUTE_DTYPE))[:, 0]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:].astype(jnp.float32)

    k = cfg.conv_kernel
    hist = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)  # [B,k,cd]
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"].astype(COMPUTE_DTYPE)) \
        + p["conv_b"].astype(COMPUTE_DTYPE)
    xBC_c = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    xs = xBC_c[..., :d_in].reshape(B_, H, cfg.ssm_headdim).astype(jnp.float32)
    Bm = xBC_c[..., d_in:d_in + N].astype(jnp.float32)
    Cm = xBC_c[..., d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])                    # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                    # [B,H]
    ssm = cache["ssm"] * decay[..., None, None] + \
        (dt[..., None] * xs)[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm) + xs * p["D"][None, :, None]
    y = y.reshape(B_, d_in).astype(COMPUTE_DTYPE)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("be,ed->bd", y,
                         p["out_proj"].astype(COMPUTE_DTYPE))[:, None]
    return out, dict(conv=new_conv, ssm=ssm, idx=cache["idx"] + 1)


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["embed"], a["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model)
    p["layers"], a["layers"] = stack_init(lambda k: init_block(k, cfg),
                                          ks[1], cfg.num_layers)
    p["final_norm"], a["final_norm"] = zeros_init((cfg.d_model,)), ("embed",)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = init_embedding(ks[2], cfg.vocab_size,
                                                    cfg.d_model)
    return p, a


def _logits(params, hidden, cfg):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden)


def loss_fn(params, batch, cfg, **_):
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed(params["embed"], tokens)

    def body(carry, lp):
        h, aux = carry
        f = ckpt(lambda q, hh: block_forward(q, hh, cfg))
        h2, a2 = f(lp, h)
        return (h2, aux + a2), None

    (x, aux), _ = maybe_scan(body, (x, jnp.float32(0)), params["layers"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = cross_entropy(_logits(params, hidden, cfg), labels)
    return ce, dict(ce=ce, aux=aux)


def init_cache(cfg, batch: int, max_seq: int):
    d_in, H, N, conv_dim = _dims(cfg)
    L, k = cfg.num_layers, cfg.conv_kernel
    c = dict(
        conv=jnp.zeros((L, batch, k - 1, conv_dim), COMPUTE_DTYPE),
        ssm=jnp.zeros((L, batch, H, cfg.ssm_headdim, N), jnp.float32),
        idx=jnp.zeros((L, batch), jnp.int32),
    )
    a = dict(conv=("layers", "batch", None, "ffn"),
             ssm=("layers", "batch", "q_heads", None, "state"),
             idx=("layers", "batch"))
    return c, a


def prefill(params, tokens, cfg, pad_cache_to=None, **_):
    del pad_cache_to  # state-based cache: no sequence axis to grow
    B_, T = tokens.shape
    x = embed(params["embed"], tokens)

    def body(h, lp):
        h2, (conv_s, ssm_s) = block_forward(lp, h, cfg, want_state=True)
        return h2, dict(conv=conv_s, ssm=ssm_s,
                        idx=jnp.full((h.shape[0],), T, jnp.int32))

    x, cache = maybe_scan(body, x, params["layers"])
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, hidden[:, -1:], cfg), cache


def decode_step(params, cache, token, cfg):
    x = embed(params["embed"], token)

    def body(h, xs):
        lp, c = xs
        h2, c2 = block_decode(lp, h, cfg, c)
        return h2, c2

    x, new_cache = maybe_scan(body, x, (params["layers"], cache))
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, hidden, cfg), new_cache
