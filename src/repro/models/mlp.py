"""Feed-forward blocks: SwiGLU / GeGLU / squared-ReLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import COMPUTE_DTYPE, dense_init


def init_mlp(key, d_model: int, d_ff: int, kind: str):
    gated = kind in ("swiglu", "geglu")
    ks = jax.random.split(key, 3)
    p = dict(w_up=dense_init(ks[0], (d_model, d_ff), d_model),
             w_down=dense_init(ks[1], (d_ff, d_model), d_ff))
    a = dict(w_up=("embed", "ffn"), w_down=("ffn", "embed"))
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), d_model)
        a["w_gate"] = ("embed", "ffn")
    return p, a


def _act(h, kind: str):
    if kind == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(kind)


def mlp_forward(p, x, kind: str):
    up = jnp.einsum("btd,df->btf", x, p["w_up"].astype(COMPUTE_DTYPE))
    if kind == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(COMPUTE_DTYPE))
        h = jax.nn.silu(g) * up
    elif kind == "geglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(COMPUTE_DTYPE))
        h = jax.nn.gelu(g) * up
    else:
        h = _act(up, kind)
    return jnp.einsum("btf,fd->btd", h, p["w_down"].astype(COMPUTE_DTYPE))
