"""Model registry: family -> module implementing the model API.

API every family module provides:
  init_params(cfg, key) -> (params, axes)
  loss_fn(params, batch, cfg) -> (loss, metrics)
  prefill(params, tokens, cfg, **extra) -> (logits, cache)
  decode_step(params, cache, token, cfg) -> (logits, cache)
  init_cache(cfg, batch, max_seq) -> (cache, axes)
"""
from __future__ import annotations

from types import ModuleType

from repro.models import encdec, mamba2, rglru, transformer, vlm


def get_model(cfg) -> ModuleType:
    if cfg.family == "ssm":
        return mamba2
    if cfg.family == "hybrid":
        return rglru
    if cfg.family == "audio":
        return encdec
    if cfg.family == "vlm":
        return vlm
    return transformer  # dense | moe
