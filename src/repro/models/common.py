"""Shared model components: norms, RoPE, embeddings, init helpers.

Convention: every `init_*` returns `(params, axes)` — two pytrees with
identical structure, where each axes leaf is a tuple of *logical* axis names
(one per array dim). The sharding rules engine (sharding/rules.py) maps
logical axes to mesh axes. Stacked-layer params get a leading "layers" axis
(never sharded; scanned over).

Compute dtype is bf16 for matmuls, fp32 for softmax/norm/reductions;
parameters are stored bf16 (fp32 masters live in the optimizer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key, shape, fan_in, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) /
            jnp.sqrt(jnp.maximum(fan_in, 1))).astype(dtype)


def zeros_init(shape, dtype=PARAM_DTYPE):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype=PARAM_DTYPE):
    return jnp.ones(shape, dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_tables(positions: jnp.ndarray, dim: int, theta: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [...] -> (cos, sin) [..., dim//2] fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [..., T, H, D]; cos/sin [..., T, D//2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def init_embedding(key, vocab: int, d_model: int):
    p = dict(table=dense_init(key, (vocab, d_model), d_model))
    a = dict(table=("vocab", "embed"))
    return p, a


def embed(params, tokens):
    return params["table"][tokens].astype(COMPUTE_DTYPE)


def unembed(params, x):
    """Logits in fp32 (vocab-sharded logsumexp-friendly)."""
    return jnp.einsum("...d,vd->...v", x.astype(COMPUTE_DTYPE),
                      params["table"]).astype(jnp.float32)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE. logits [B,T,V] fp32, labels [B,T] int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


# ---------------------------------------------------------------------------
# scan-vs-unroll: XLA's cost_analysis() counts a while-loop body ONCE,
# ignoring the trip count, so scanned-layer models under-report FLOPs/bytes
# by ~L x microbatches. The dry-run calibrates corrected roofline terms by
# compiling small configurations with every scan unrolled (this context) and
# solving the linear cost model — see launch/dryrun.py.
# ---------------------------------------------------------------------------
import contextlib as _contextlib
import threading as _threading

_unroll_local = _threading.local()


@_contextlib.contextmanager
def unroll_scans(flag: bool = True):
    prev = getattr(_unroll_local, "flag", False)
    _unroll_local.flag = flag
    try:
        yield
    finally:
        _unroll_local.flag = prev


def unrolling() -> bool:
    return getattr(_unroll_local, "flag", False)


_policy_local = _threading.local()


@_contextlib.contextmanager
def remat_policy(name: str):
    """Active rematerialization policy for layer scans:
    "full" (save nothing — default), "dots" (save matmul outputs),
    "none" (no remat)."""
    prev = getattr(_policy_local, "name", "full")
    _policy_local.name = name
    try:
        yield
    finally:
        _policy_local.name = prev


def ckpt(f):
    """jax.checkpoint with the active policy (see remat_policy)."""
    name = getattr(_policy_local, "name", "full")
    if name == "none":
        return f
    if name == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(f)


def maybe_scan(f, init, xs, length=None):
    """jax.lax.scan, or a Python unroll under `unroll_scans()` (identical
    semantics; used so cost_analysis sees every iteration)."""
    if not unrolling():
        return jax.lax.scan(f, init, xs, length=length)
    n = length if xs is None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree_util.tree_map(
            lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and jax.tree_util.tree_leaves(ys[0]):
        ys_st = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys_st = None
    return carry, ys_st


def prepend_layers_axis(axes_tree):
    return jax.tree_util.tree_map(lambda a: ("layers",) + a, axes_tree,
                                  is_leaf=_is_axes_leaf)


def stack_init(init_fn, key, n_layers: int):
    """vmap `init_fn(key) -> (params, axes)` over layer keys; returns
    params stacked on a leading (scanned, never-sharded) 'layers' axis."""
    keys = jax.random.split(key, n_layers)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(keys[0])
    return params, prepend_layers_axis(axes)
