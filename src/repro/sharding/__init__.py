from repro.sharding.rules import (ShardingRules, active_rules, default_rules,
                                  maybe_constrain)

__all__ = ["ShardingRules", "active_rules", "default_rules", "maybe_constrain"]
