"""Logical-axis sharding rules (MaxText-style).

Model code names array dims with *logical* axes ("batch", "q_heads", "ffn",
"experts", "cache_seq", ...). A ShardingRules instance maps logical axes to
mesh axes and produces NamedShardings / PartitionSpecs. A dim mapping is
dropped (replicated) when the dim is smaller than the mesh axis it would
shard over; uneven-but-larger dims rely on GSPMD padding (verified
supported).

`maybe_constrain` lets layer code place constraints without threading the
rules object through every call — a context manager installs the active
rules; with no active rules (CPU unit tests) it is the identity.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# default logical-axis -> mesh-axes mapping; "pod" exists only multi-pod
def default_rules(multi_pod: bool) -> Dict[str, Tuple[str, ...]]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "q_heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "ffn": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        # weights: d_model dim sharded over data => FSDP-at-rest /256; the
        # per-tensor used-set keeps activations (whose batch dim already
        # holds the data axis) replicated on their embed dim. GSPMD inserts
        # the per-layer weight all-gathers / gradient reduce-scatters.
        "embed": ("pod", "data") if multi_pod else ("data",),
        "q_lora": ("pod", "data") if multi_pod else ("data",),
        "kv_lora": ("pod", "data") if multi_pod else ("data",),
        "q_lora": (),
        "kv_lora": (),
        "layers": (),           # scanned, never sharded
        "seq": (),              # training seq unsharded (batch-parallel)
        "q_lora_act": (),       # activation-side latent dims stay replicated
        "kv_lora_act": (),
        "cache_seq": ("model",),  # decode KV split (flash-decoding layout)
        # MoE expert buffers [E, C, d]: E over model (expert parallel) AND
        # capacity over data — without the C mapping every data row computes
        # identical expert work (measured 16x FLOP redundancy; §Perf)
        "moe_capacity": ("pod", "data") if multi_pod else ("data",),
        "moe_tokens": ("pod", "data") if multi_pod else ("data",),
        "state": (),            # SSM state
        "groups": (),
        # ZeRO: flattened optimizer state spreads over every axis available
        "zero": ("pod", "data", "model") if multi_pod else ("data", "model"),
    }


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]

    def _axis_size(self, mesh_axes: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes])) \
            if mesh_axes else 1

    def spec(self, logical_axes: Tuple[Optional[str], ...],
             shape: Optional[Tuple[int, ...]] = None) -> P:
        parts = []
        used = set()
        for i, ax in enumerate(logical_axes):
            mesh_axes = tuple(a for a in self.rules.get(ax, ()) or ()
                              if a in self.mesh.shape and a not in used)
            if not mesh_axes:
                parts.append(None)
                continue
            if shape is not None and shape[i] % self._axis_size(mesh_axes) != 0:
                # pjit arg shardings require even divisibility; replicate
                # instead (e.g. kv_heads < TP degree, odd vocab sizes)
                parts.append(None)
                continue
            used.update(mesh_axes)
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return P(*parts)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, logical_axes):
        return jax.lax.with_sharding_constraint(
            x, self.sharding(logical_axes, x.shape))

    def tree_shardings(self, shapes_tree, axes_tree):
        """NamedSharding pytree for (eval_shape-tree, logical-axes-tree)."""
        def one(sds, axes):
            return self.sharding(axes, tuple(sds.shape))
        return jax.tree_util.tree_map(
            one, shapes_tree, axes_tree,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


_local = threading.local()


@contextlib.contextmanager
def active_rules(rules: Optional[ShardingRules]):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield
    finally:
        _local.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_local, "rules", None)


def maybe_constrain(x, logical_axes):
    rules = getattr(_local, "rules", None)
    if rules is None:
        return x
    return rules.constrain(x, logical_axes)
