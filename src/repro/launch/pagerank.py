"""Distributed PageRank driver: sharded engines + fault tolerance.

    PYTHONPATH=src python -m repro.launch.pagerank --n 512 --eps 0.2 \
        --walks 64 --graph erdos_renyi --algo improved

Engine selection (`--algo`):
  walks     Algorithm 1, walk-routing shard_map engine (default).
  counts    Algorithm 1, count-aggregated engine (Lemma-1 wire: per-vertex
            coupon counts, payload independent of the walk count).
  improved  Algorithm 2 (IMPROVED-PAGERANK), three-phase sharded engine:
            sqrt(log n)-length short-walk pre-computation, count-
            aggregated coupon stitching, one-exchange owner-shard visit
            counting (see `repro.core.distributed_improved`). All three
            phases move Lemma-1 aggregated (vertex, count) payloads.
  directed  Section 5 (directed/LOCAL), the same three-phase engine with
            uniform per-node coupon budgets, lam = sqrt(log n / eps)
            short walks, and dangling-node resets (see
            `repro.core.distributed_directed`). Count aggregation retired
            the worst-case LOCAL buffers this engine used to need: lane
            volume is bounded by distinct vertices, not walk multiplicity.
            Pair it with `--graph directed_web` to exercise a power-law
            directed fixture.

`--use-pallas` routes every engine's hot paths (walk stepping, arrival
histograms, count reductions) through the Pallas kernels in
`repro.kernels` — interpret mode on CPU, compiled on TPU. The kernels
share decision logic and uniforms with the jnp fallbacks, so results are
bit-identical either way; the REPRO_USE_PALLAS env var is the flagless
default (the `counts` engine takes only the env var).

Fault tolerance applies to EVERY engine: `--checkpoint-dir` enables
periodic snapshots, `--fail-at R [R ...]` injects simulated failures at
the listed global rounds (for the 3-phase engines, round indices span all
phases, so a failure can land at a phase boundary or mid-phase), and
recovery from the latest snapshot is bit-exact — the recovered run prints
the same pi, telemetry, and accuracy as an unfailed one, plus restarts>0.
`--resume` cold-starts from the latest snapshot in --checkpoint-dir (a
previously killed run) instead of from round 0.

Elastic resume: snapshots record the mesh size they were written under
and every engine declares a per-buffer layout schema
(`checkpoint.LayoutSpec`: walk lanes, vertex shards, coupon slots,
per-shard keys, replicated scalars — see `checkpoint/elastic.py`), so
`--resume` does NOT need the original device count. Pass `--shards N` to
run on the first N local devices; when N differs from the snapshot's
recorded shard count, restore routes through the schema-driven relayout
and the run continues on the resized mesh. The count-state engine
(`--algo counts`) resumes BIT-exactly at any N (its RNG is counter-based
per vertex and its round key replicated); the 3-phase engines resume
bit-exactly from RNG-free stages (mid-Phase-2/3) and statistically —
gated by the same `--check` tolerances — when live per-shard key streams
had to be re-derived. `--shards` also works without `--resume`, simply
running any engine on a submesh.

Every run validates against power iteration (L1 and top-10 overlap);
`--check` turns that report into a hard gate (non-zero exit on miss) for
CI smoke legs.

Telemetry printed for `--algo improved` and `--algo directed` (also
available on the returned `ImprovedDistResult`/`DirectedDistResult`):
  phase rounds   per-phase superstep counts: phase1 (short walks, <= lam),
                 report (always 0 — coupons never migrate, so the old
                 coupon-summary report phase no longer exists; the column
                 stays as a regression tripwire), phase2 (stitching),
                 phase3 (always 1 — counting is ONE aggregated exchange
                 over the home-local trajectory tables, not a replay),
                 tail (naive fallback) — their sum is the engine's total
                 round count, the quantity the paper bounds by
                 O(sqrt(log n)/eps) undirected resp. O(sqrt(log n / eps))
                 directed.
  coupons        created vs used pool sizes and exhausted walks (pool
                 ran dry -> naive fallback).
  wire           all_to_all payload bytes by phase. Every phase ships
                 Lemma-1 aggregated (vertex, count) entries — 8 B/entry
                 for stitch/count traffic, 8+12 B/entry for the Phase-1
                 request/reply — and each column is charged as
                 entries * entry_nbytes(<the routed columns>), derived
                 from the actual lane dtypes (never a hand-kept
                 constant). `dropped` (lane overflows) must be 0;
                 `waited` counts tail-lane carry-overs.
  budget         (`directed` only) the uniform per-node coupon budget and
                 the dangling-node count (out-degree 0, immediate reset).
  sampler        (`counts`, `improved`, `directed`) degree-bucketed
                 aggregate-sampler telemetry: total and per-round wall
                 microseconds inside the sample program, per-bucket
                 occupancy (rows holding coupons, summed over rounds and
                 shards; bucket b covers degrees in (2^(b-1), 2^b]), and
                 the conservation residual (must be 0).

`--algo ppr` runs the batched Personalized-PageRank engine
(`repro.core.personalized_batch`): `--queries` seed-derived multi-source
queries advance together, every superstep moving ALL queries' walks over
one `route_counts` exchange (query ids folded into a virtual vertex
space, so the wire stays Lemma-1 counts). Telemetry printed:
  rounds         supersteps to drain every query's walks.
  a2a_bytes      total all_to_all payload (8 B per routed (vertex-lane,
                 count) entry, summed over rounds).
  dropped / admit_dropped
                 walk-buffer resp. admission overflow — both must be 0
                 (the default cap is sized so overflow is impossible).
  peak_active    peak concurrently-live walks across the run (from the
                 per-round active trace).
Accuracy is reported per query against the `exact_ppr` dense linear
solve (NOT power iteration — PPR's stationary vector depends on the
query's source distribution); `--check` gates on the same L1/top-10
thresholds as the global-PageRank algos.

`--audit` runs the CONGEST auditor instead of an engine: every engine's
jitted stage programs are traced to jaxprs (the engines' own memoized
programs — identical cache keys, so the trace IS the runtime program),
each all_to_all is checked against its declared per-round lane budget,
the RNG / dtype / elastic-schema lints run over the same traces, the
engines execute on fixture graphs to cross-check the static widths
against runtime telemetry, and AUDIT.json is written next to the table.
Non-zero exit on any violation. Per-engine wire budgets (P = shards,
n_loc = ceil(n/P), md = max degree, Q = PPR query slots; every entry is
a Lemma-1 (vertex, count) cell except the walk-class lanes, whose caps
the auditor pins at n_loc so the checked capacity stays W-free):

  engine    site         B/entry  per-shard-per-round lane budget
  walks     route          4      P * n_loc walk slots       [walk-class]
  counts    counts         4      P * min(cut_max, n_loc) cells
  improved  phase1_req     8      P * n_loc cells
            phase1_rep    12      P * n_loc * (md+1) (vertex,class,count)
            phase2         8      P * n_loc cells
            phase3         8      P * n_loc cells
            tail           4      P * n_loc walk slots       [walk-class]
  directed  same five sites as improved (uniform-budget coupon pools)
  ppr       ppr            8      P * n_loc * Q (vertex, query) lanes

No budget depends on the walk multiplicity W: the auditor rebuilds every
spec at 2x walks and fails if any budget moves. The RNG lint also
certifies which stages resume bit-exactly after an elastic restore:
`counts` (replicated round key, counter-based RNG) and the 3-phase
engines' phase2/phase3 programs (RNG-free) are bit-exact; walks, phase1,
tail, and ppr consume per-shard key streams that are re-derived on a
resized mesh, so their resume is statistical (tolerance-gated).
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer, relayout_pagerank_state
from repro.core import l1_error, normalized, power_iteration, topk_overlap
from repro.core.distributed import (AXIS, DistState, _make_superstep,
                                    shard_graph, state_from_host,
                                    state_to_host)
from repro.core.distributed_counts import distributed_pagerank_counts
from repro.core.distributed_directed import distributed_directed_pagerank
from repro.core.distributed_improved import distributed_improved_pagerank
from repro.graphs import GENERATORS
from repro.runtime import FailureSchedule, Supervisor

import jax.numpy as jnp


def _report_accuracy(pi, g, eps: float, check: bool = False,
                     l1_tol: float = 0.15, topk_min: float = 0.6) -> None:
    pi = np.asarray(pi, dtype=np.float64)
    pi_ref, _, _ = power_iteration(g, eps)
    l1 = l1_error(pi / pi.sum(), pi_ref)
    topk = topk_overlap(pi, np.asarray(pi_ref))
    print(f"[pagerank] L1 vs power-iter: {l1:.4f}  "
          f"top-10 overlap: {topk:.2f}")
    if check and (l1 >= l1_tol or topk < topk_min):
        raise SystemExit(
            f"[pagerank] accuracy check FAILED: L1 {l1:.4f} "
            f"(tol {l1_tol}) top-10 {topk:.2f} (min {topk_min})")


def run_walks(g, eps: float, walks_per_node: int, checkpoint_dir,
              fail_at, seed: int, resume: bool = False,
              use_pallas: bool = False, mesh=None,
              max_restarts: int = 16):
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    shards = mesh.devices.size
    sg = shard_graph(g, shards)
    W = g.n * walks_per_node
    cap = 2 * W // shards + shards * 64
    route_cap = W // shards + 64

    pos0 = np.full((shards, cap), -1, np.int32)
    zeta0 = np.zeros((shards, sg.n_loc), np.int32)
    for p in range(shards):
        lo = min(p * sg.n_loc, g.n)
        hi = min((p + 1) * sg.n_loc, g.n)
        locs = np.repeat(np.arange(lo, hi, dtype=np.int32), walks_per_node)
        pos0[p, : len(locs)] = locs
        zeta0[p, : hi - lo] = walks_per_node
    spec = NamedSharding(mesh, P(AXIS))
    keys = jax.random.split(jax.random.PRNGKey(seed), shards)
    state = DistState(pos=jax.device_put(jnp.asarray(pos0), spec),
                      zeta=jax.device_put(jnp.asarray(zeta0), spec),
                      key=jax.device_put(keys, spec),
                      round=jnp.int32(0), dropped=jnp.int32(0),
                      waited=jnp.int32(0))
    rp, ci, dg = (jax.device_put(x, spec)
                  for x in (sg.row_ptr, sg.col_idx, sg.out_deg))
    step = _make_superstep(mesh, eps, sg.n_loc, shards, route_cap, 0,
                           use_pallas=use_pallas)

    def step_fn(s):
        s2, active, _, _ = step(rp, ci, dg, s)
        return s2, int(active) == 0

    ckpt_dir = checkpoint_dir or tempfile.mkdtemp(prefix="pr_ckpt_")
    sup = Supervisor(step_fn, state_to_host,
                     lambda f: state_from_host(f, mesh),
                     Checkpointer(ckpt_dir), checkpoint_every=10,
                     max_restarts=max_restarts,
                     failure_schedule=FailureSchedule(fail_at) if fail_at
                     else None,
                     meta_fn=lambda: dict(shards=shards),
                     relayout=lambda f, old: relayout_pagerank_state(
                         f, g.n, shards, cap=cap))
    res = sup.run(state, resume=resume)
    zeta = np.asarray(res.state.zeta).reshape(-1)[: g.n]
    pi = zeta.astype(np.float64) * eps / (g.n * walks_per_node)
    print(f"[pagerank] algo=walks n={g.n} shards={shards} "
          f"rounds={res.rounds} restarts={res.restarts} "
          f"dropped={int(res.state.dropped)}")
    return pi


def run_ppr(g, eps: float, walks_per_query: int, num_queries: int,
            seed: int, check: bool = False, use_pallas: bool = False,
            l1_tol: float = 0.15, topk_min: float = 0.6, mesh=None):
    """Batched PPR: seed-derived multi-source queries, one shared engine.

    Validates each query against its OWN `exact_ppr` oracle — PPR has no
    single power-iteration reference, so this path never reaches
    `_report_accuracy`. Returns the [num_queries, n] estimator matrix.
    """
    from repro.core.personalized import exact_ppr
    from repro.core.personalized_batch import batched_personalized_pagerank

    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(num_queries):
        k = int(rng.integers(1, 4))
        sources = rng.choice(g.n, size=k, replace=False)
        queries.append((sources, None))
    res = batched_personalized_pagerank(
        g, eps, queries, walks_per_query, jax.random.PRNGKey(seed),
        mesh=mesh, use_pallas=use_pallas or None)
    peak = max(res.active_trace) if res.active_trace else 0
    print(f"[pagerank] algo=ppr n={g.n} shards={res.shards} "
          f"queries={num_queries} walks/query={walks_per_query} "
          f"rounds={res.rounds} a2a_bytes={res.a2a_bytes} "
          f"dropped={res.dropped} admit_dropped={res.admit_dropped} "
          f"peak_active={peak}")
    worst_l1, worst_topk = 0.0, 1.0
    for i, (sources, weights) in enumerate(queries):
        ref = exact_ppr(g, eps, sources, weights=weights)
        est = res.ppr[i]
        l1 = l1_error(normalized(est), normalized(ref))
        topk = topk_overlap(est, ref)
        print(f"[pagerank]   query {i} sources={list(map(int, sources))} "
              f"L1 vs exact_ppr: {l1:.4f}  top-10 overlap: {topk:.2f}")
        worst_l1, worst_topk = max(worst_l1, l1), min(worst_topk, topk)
    if check and (worst_l1 >= l1_tol or worst_topk < topk_min
                  or res.dropped or res.admit_dropped):
        raise SystemExit(
            f"[pagerank] ppr check FAILED: worst L1 {worst_l1:.4f} "
            f"(tol {l1_tol}) worst top-10 {worst_topk:.2f} "
            f"(min {topk_min}) dropped={res.dropped} "
            f"admit_dropped={res.admit_dropped}")
    return res.ppr


def run(n: int, eps: float, walks_per_node: int, graph_kind: str,
        checkpoint_dir: str | None, fail_at: list[int], seed: int = 0,
        algo: str = "walks", avg_deg: float = 6.0, resume: bool = False,
        check: bool = False, use_pallas: bool = False,
        num_queries: int = 4, shards: int | None = None,
        max_restarts: int = 16):
    if resume and not checkpoint_dir:
        raise SystemExit("[pagerank] --resume needs --checkpoint-dir "
                         "(there is no snapshot to cold-start from)")
    mesh = None
    if shards is not None:
        devs = jax.devices()
        if not 1 <= shards <= len(devs):
            raise SystemExit(f"[pagerank] --shards {shards} out of range: "
                             f"{len(devs)} devices available")
        mesh = Mesh(np.array(devs[:shards]), (AXIS,))
    g = GENERATORS[graph_kind](n, avg_deg, seed) if graph_kind != "ring" \
        else GENERATORS[graph_kind](n)
    if algo == "ppr":
        # PPR validates per-query vs exact_ppr inside run_ppr; the
        # power-iteration report below does not apply to it
        return run_ppr(g, eps, walks_per_node * g.n, num_queries, seed,
                       check=check, use_pallas=use_pallas, mesh=mesh)
    if algo == "walks":
        pi = run_walks(g, eps, walks_per_node, checkpoint_dir, fail_at,
                       seed, resume=resume, use_pallas=use_pallas,
                       mesh=mesh, max_restarts=max_restarts)
    elif algo == "counts":
        res = distributed_pagerank_counts(
            g, eps, walks_per_node, jax.random.PRNGKey(seed), mesh=mesh,
            checkpoint_dir=checkpoint_dir, fail_at=fail_at, resume=resume,
            max_restarts=max_restarts,
            use_pallas=use_pallas or None)
        print(f"[pagerank] algo=counts n={g.n} shards={res.shards} "
              f"rounds={res.rounds} restarts={res.restarts} "
              f"lane_cap={res.lane_cap} "
              f"a2a_bytes={res.a2a_bytes_total} overflow={res.overflow}")
        print(f"[pagerank] sampler: {res.sampler_us:.0f} us total "
              f"({res.sampler_us / max(res.rounds, 1):.0f} us/round) "
              f"bucket_occupancy={list(res.occupancy)} "
              f"residual={res.residual}")
        pi = res.pi
    elif algo in ("improved", "directed"):
        engine = (distributed_improved_pagerank if algo == "improved"
                  else distributed_directed_pagerank)
        res = engine(g, eps, walks_per_node, jax.random.PRNGKey(seed),
                     mesh=mesh, checkpoint_dir=checkpoint_dir,
                     fail_at=fail_at, resume=resume,
                     max_restarts=max_restarts, use_pallas=use_pallas)
        print(f"[pagerank] algo={algo} n={g.n} shards={res.shards} "
              f"lam={res.lam} eta={res.eta} ell={res.ell} "
              f"rounds={res.rounds} restarts={res.restarts} "
              f"(p1={res.phase1_rounds} "
              f"report={res.report_rounds} p2={res.phase2_rounds} "
              f"p3={res.phase3_rounds} tail={res.tail_rounds})")
        print(f"[pagerank] coupons created={res.coupons_created} "
              f"used={res.coupons_used} exhausted_walks="
              f"{res.exhausted_walks} tail_walks={res.tail_walks}")
        print(f"[pagerank] wire by phase: {res.a2a_bytes_by_phase} "
              f"dropped={res.dropped} waited={res.waited}")
        print(f"[pagerank] p1 sampler: {res.sampler_us:.0f} us total "
              f"({res.sampler_us / max(res.phase1_rounds, 1):.0f} us/round)"
              f" bucket_occupancy={list(res.p1_occupancy)} "
              f"residual={res.residual}")
        if algo == "directed":
            print(f"[pagerank] uniform budget={res.uniform_budget} "
                  f"coupons/node dangling_nodes={res.dangling_nodes}")
        pi = res.pi
    else:
        raise ValueError(f"unknown algo {algo!r}")
    _report_accuracy(pi, g, eps, check=check)
    return pi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--eps", type=float, default=0.2)
    ap.add_argument("--walks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0,
                    help="graph-generator and PRNG seed")
    ap.add_argument("--avg-deg", type=float, default=6.0,
                    help="generator degree parameter (ignored by ring)")
    ap.add_argument("--graph", default="erdos_renyi",
                    choices=sorted(GENERATORS))
    ap.add_argument("--algo", default="walks",
                    choices=["walks", "counts", "improved", "directed",
                             "ppr"])
    ap.add_argument("--queries", type=int, default=4,
                    help="(--algo ppr) number of seed-derived multi-"
                         "source queries batched into one engine; each "
                         "query gets --walks * n walks")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--resume", action="store_true",
                    help="cold-start from the latest snapshot in "
                         "--checkpoint-dir instead of round 0. The "
                         "snapshot's mesh size does NOT have to match: "
                         "combine with --shards N to resume a run killed "
                         "at a different device count (elastic relayout; "
                         "bit-exact for --algo counts, tolerance-gated "
                         "when live per-shard key streams are re-derived)")
    ap.add_argument("--shards", type=int, default=None,
                    help="run on the first N local devices instead of all "
                         "of them; with --resume, the mesh size to resume "
                         "ONTO (may differ from the snapshot's)")
    ap.add_argument("--max-restarts", type=int, default=16,
                    help="supervisor restart budget before an injected "
                         "failure is re-raised (0 = die on first failure, "
                         "leaving the snapshot dir for an elastic resume)")
    ap.add_argument("--check", action="store_true",
                    help="non-zero exit if the accuracy report misses "
                         "L1 < 0.15 / top-10 >= 0.6 (CI smoke gate)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route the hot paths through the Pallas kernels "
                         "(bit-identical results; interpret mode on CPU). "
                         "REPRO_USE_PALLAS=1 is the flagless equivalent")
    ap.add_argument("--audit", action="store_true",
                    help="run the CONGEST wire-budget + lint auditor over "
                         "every engine instead of a PageRank run: prints "
                         "the per-engine wire table, writes AUDIT.json, "
                         "exits non-zero on any violation (see the module "
                         "docstring for the budget table)")
    args = ap.parse_args()
    if args.audit:
        import json

        from repro.analysis.congest import (audit_all_engines,
                                            format_wire_table)
        report = audit_all_engines(use_pallas=args.use_pallas, eps=args.eps)
        print(format_wire_table(report))
        with open("AUDIT.json", "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print("[pagerank] wrote AUDIT.json")
        if not report["ok"]:
            raise SystemExit("[pagerank] CONGEST audit FAILED")
        return
    run(args.n, args.eps, args.walks, args.graph, args.checkpoint_dir,
        args.fail_at, seed=args.seed, algo=args.algo, avg_deg=args.avg_deg,
        resume=args.resume, check=args.check, use_pallas=args.use_pallas,
        num_queries=args.queries, shards=args.shards,
        max_restarts=args.max_restarts)


if __name__ == "__main__":
    main()
