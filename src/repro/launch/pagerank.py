"""Distributed PageRank driver: sharded engine + fault tolerance.

    PYTHONPATH=src python -m repro.launch.pagerank --n 512 --eps 0.2 \
        --walks 64 --graph erdos_renyi --checkpoint-dir /tmp/pr_ckpt

Runs Algorithm 1 on all available devices via the shard_map engine under
the checkpoint-restart supervisor (optionally with injected failures to
demonstrate exact recovery), then validates against power iteration.
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer
from repro.core import l1_error, normalized, power_iteration, topk_overlap
from repro.core.distributed import (AXIS, DistState, _make_superstep,
                                    shard_graph, state_from_host,
                                    state_to_host)
from repro.graphs import GENERATORS
from repro.runtime import FailureSchedule, Supervisor

import jax.numpy as jnp


def run(n: int, eps: float, walks_per_node: int, graph_kind: str,
        checkpoint_dir: str | None, fail_at: list[int], seed: int = 0):
    g = GENERATORS[graph_kind](n, 6.0, seed) if graph_kind != "ring" \
        else GENERATORS[graph_kind](n)
    devs = np.array(jax.devices())
    mesh = Mesh(devs, (AXIS,))
    shards = devs.size
    sg = shard_graph(g, shards)
    W = g.n * walks_per_node
    cap = 2 * W // shards + shards * 64
    route_cap = W // shards + 64

    pos0 = np.full((shards, cap), -1, np.int32)
    zeta0 = np.zeros((shards, sg.n_loc), np.int32)
    for p in range(shards):
        lo = min(p * sg.n_loc, g.n)
        hi = min((p + 1) * sg.n_loc, g.n)
        locs = np.repeat(np.arange(lo, hi, dtype=np.int32), walks_per_node)
        pos0[p, : len(locs)] = locs
        zeta0[p, : hi - lo] = walks_per_node
    spec = NamedSharding(mesh, P(AXIS))
    keys = jax.random.split(jax.random.PRNGKey(seed), shards)
    state = DistState(pos=jax.device_put(jnp.asarray(pos0), spec),
                      zeta=jax.device_put(jnp.asarray(zeta0), spec),
                      key=jax.device_put(keys, spec),
                      round=jnp.int32(0), dropped=jnp.int32(0),
                      waited=jnp.int32(0))
    rp, ci, dg = (jax.device_put(x, spec)
                  for x in (sg.row_ptr, sg.col_idx, sg.out_deg))
    step = _make_superstep(mesh, eps, sg.n_loc, shards, route_cap, 0)

    def step_fn(s):
        s2, active, _ = step(rp, ci, dg, s)
        return s2, int(active) == 0

    ckpt_dir = checkpoint_dir or tempfile.mkdtemp(prefix="pr_ckpt_")
    sup = Supervisor(step_fn, state_to_host,
                     lambda f: state_from_host(f, mesh),
                     Checkpointer(ckpt_dir), checkpoint_every=10,
                     failure_schedule=FailureSchedule(fail_at) if fail_at
                     else None)
    res = sup.run(state)
    zeta = np.asarray(res.state.zeta).reshape(-1)[: g.n]
    pi = zeta.astype(np.float64) * eps / (g.n * walks_per_node)
    pi_ref, _, _ = power_iteration(g, eps)
    print(f"[pagerank] n={n} shards={shards} rounds={res.rounds} "
          f"restarts={res.restarts} dropped={int(res.state.dropped)}")
    print(f"[pagerank] L1 vs power-iter: "
          f"{l1_error(pi / pi.sum(), pi_ref):.4f}  "
          f"top-10 overlap: {topk_overlap(pi, np.asarray(pi_ref)):.2f}")
    return pi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--eps", type=float, default=0.2)
    ap.add_argument("--walks", type=int, default=64)
    ap.add_argument("--graph", default="erdos_renyi",
                    choices=sorted(GENERATORS))
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    run(args.n, args.eps, args.walks, args.graph, args.checkpoint_dir,
        args.fail_at)


if __name__ == "__main__":
    main()
