import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the *real* step function is lowered against
ShapeDtypeStruct inputs (no allocation) on the production mesh and
compiled; we record:
    memory_analysis()  — proves the cell fits per-device HBM,
    cost_analysis()    — HLO FLOPs / bytes for §Roofline,
    HLO collective ops — payload bytes per collective kind (§Roofline).

Results land in results/dryrun/<cell>.json; existing cells are skipped so
the sweep is restartable cell-by-cell (run via scripts or
`python -m repro.launch.dryrun --all`).

Cell kinds:
    train_4k    -> train_step (loss + grads + AdamW/ZeRO update)
    prefill_32k -> model.prefill
    decode_32k / long_500k -> model.decode_step against a full cache
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.roofline import build_roofline, model_flops_for
from repro.configs import (ARCHS, SHAPES, get_config, input_specs,
                           reduced_config, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.sharding.rules import ShardingRules, active_rules, default_rules
from repro.train import AdamWConfig, init_state, make_train_step, state_axes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def microbatches_for(cfg, multi_pod: bool) -> int:
    """Per-device microbatch ~1-2 sequences for huge models."""
    n = cfg.param_count()
    if n > 100e9:
        return 16
    if n > 30e9:
        return 4
    return 1


def int8_for(cfg) -> bool:
    return cfg.param_count() > 100e9


def param_axes_of(cfg, model):
    """Logical-axes tree via a reduced same-structure init (cheap)."""
    rcfg = reduced_config(cfg.name)
    _, axes = model.init_params(rcfg, jax.random.PRNGKey(0))
    return axes


def cache_axes_of(cfg, model):
    rcfg = reduced_config(cfg.name)
    _, axes = model.init_cache(rcfg, 2, 64)
    return axes


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               q_chunk: int = 512, cfg_override=None, microbatches=None,
               unroll: bool = False, remat: str = "full"):
    import contextlib

    from repro.models.common import remat_policy, unroll_scans

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh, default_rules(multi_pod))
    unroll_ctx = contextlib.ExitStack()
    if unroll:
        unroll_ctx.enter_context(unroll_scans())
    if remat != "full":
        unroll_ctx.enter_context(remat_policy(remat))

    p_axes = param_axes_of(cfg, model)
    params_sds = jax.eval_shape(
        lambda k: model.init_params(cfg, k)[0], jax.random.PRNGKey(0))
    params_sh = rules.tree_shardings(params_sds, p_axes)

    specs = input_specs(cfg, shape)

    with active_rules(rules), unroll_ctx:
        if shape.kind == "train":
            adam = AdamWConfig(int8_moments=int8_for(cfg))
            opt_sds = jax.eval_shape(partial(init_state, cfg=adam), params_sds)
            opt_sh = rules.tree_shardings(
                opt_sds, state_axes(p_axes, adam.int8_moments))
            nm = microbatches if microbatches is not None \
                else microbatches_for(cfg, multi_pod)
            step = make_train_step(cfg, model, adam, num_microbatches=nm,
                                   loss_kwargs=dict(q_chunk=q_chunk))
            batch_sh = {k: rules.sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                          v.shape)
                        for k, v in specs.items()}
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, specs)
        elif shape.kind == "prefill":
            extra = {k: v for k, v in specs.items() if k != "tokens"}

            def pre_fn(params, tokens, ex):
                return model.prefill(params, tokens, cfg, q_chunk=q_chunk,
                                     **ex)

            ex_sh = {k: rules.sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                       v.shape) for k, v in extra.items()}
            jitted = jax.jit(pre_fn,
                             in_shardings=(params_sh,
                                           rules.sharding(("batch", None),
                                                          specs["tokens"].shape),
                                           ex_sh),
                             out_shardings=None)
            lowered = jitted.lower(params_sds, specs["tokens"], extra)
        else:  # decode
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(cfg, shape.global_batch,
                                         shape.seq_len)[0])
            c_axes = cache_axes_of(cfg, model)
            cache_sh = rules.tree_shardings(cache_sds, c_axes)

            def dec_fn(params, cache, token):
                return model.decode_step(params, cache, token, cfg)

            jitted = jax.jit(dec_fn,
                             in_shardings=(params_sh, cache_sh,
                                           rules.sharding(("batch", None),
                                                          specs["token"].shape)),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, specs["token"])
    return lowered, cfg, shape, mesh


def _measure(arch, shape_name, multi_pod, q_chunk, cfg_override=None,
             microbatches=None, unroll=False, remat="full"):
    """Lower+compile, return (flops, bytes, coll_total, coll_breakdown)."""
    from repro.analysis.hlo import collective_bytes

    lowered, *_ = lower_cell(arch, shape_name, multi_pod, q_chunk=q_chunk,
                             cfg_override=cfg_override,
                             microbatches=microbatches, unroll=unroll,
                             remat=remat)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return dict(flops=float(cost.get("flops", 0.0)),
                bytes=float(cost.get("bytes accessed", 0.0)),
                coll=float(sum(coll.values())),
                breakdown=coll)


def calibrated_costs(arch: str, shape_name: str, multi_pod: bool,
                     q_chunk: int) -> dict:
    """Corrected whole-step per-chip costs.

    XLA cost_analysis counts while-loop bodies once (trip count ignored), so
    scanned-layer models under-report by ~L x. We compile small UNROLLED
    configs at 2 (or 3 for enc-dec) layer counts with full widths and solve
    the exact linear model  cost = fixed + per_layer * L  (total tokens are
    microbatch-invariant, so nm drops out of the FLOP/byte/collective
    totals). Returns per-metric corrected totals + the calibration points.
    """
    import dataclasses as dc

    import numpy as np

    cfg = get_config(arch)
    metrics = ("flops", "bytes", "coll")

    def meas(cfg_i):
        return _measure(arch, shape_name, multi_pod, q_chunk,
                        cfg_override=cfg_i, microbatches=1, unroll=True)

    if cfg.family == "audio":
        if cfg.num_layers + cfg.encoder_layers <= 8:
            m = _measure(arch, shape_name, multi_pod, q_chunk,
                         microbatches=1, unroll=True)
            return dict(corrected={k: m[k] for k in metrics},
                        breakdown=m["breakdown"], method="direct_unroll")
        pts = [(dc.replace(cfg, encoder_layers=e, num_layers=d), (1, e, d))
               for e, d in ((1, 1), (2, 1), (1, 2))]
        full_feat = (1, cfg.encoder_layers, cfg.num_layers)
    elif cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        trailing = cfg.num_layers % period
        pts = [(dc.replace(cfg, num_layers=period * g + trailing), (1, g))
               for g in (1, 2)]
        full_feat = (1, cfg.num_layers // period)
    elif cfg.num_experts and cfg.first_dense_layers:
        fd = cfg.first_dense_layers
        pts = [(dc.replace(cfg, num_layers=fd + m_), (1, m_))
               for m_ in (1, 2)]
        full_feat = (1, cfg.num_layers - fd)
    else:
        pts = [(dc.replace(cfg, num_layers=L), (1, L)) for L in (1, 2)]
        full_feat = (1, cfg.num_layers)

    feats = []
    vals = []
    bks = []
    for cfg_i, feat in pts:
        m = meas(cfg_i)
        feats.append(feat)
        vals.append([m[k] for k in metrics])
        bks.append(m["breakdown"])
    A = np.asarray(feats, dtype=np.float64)
    Y = np.asarray(vals, dtype=np.float64)
    theta, *_ = np.linalg.lstsq(A, Y, rcond=None)
    corrected = np.asarray(full_feat, np.float64) @ theta
    corrected = {k: float(max(corrected[i], 0.0))
                 for i, k in enumerate(metrics)}
    # corrected per-kind collective breakdown via the same solve
    kinds = sorted({k for b in bks for k in b})
    if kinds:
        Yb = np.asarray([[b.get(k, 0) for k in kinds] for b in bks],
                        np.float64)
        tb, *_ = np.linalg.lstsq(A, Yb, rcond=None)
        bk_corr = np.asarray(full_feat, np.float64) @ tb
        breakdown = {k: int(max(v, 0)) for k, v in zip(kinds, bk_corr)}
    else:
        breakdown = {}
    return dict(corrected=corrected, breakdown=breakdown,
                method="linear_calibration",
                points=[dict(feat=list(f), vals=dict(zip(metrics, v)))
                        for f, v in zip(feats, vals)])


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             q_chunk: int = 512, force: bool = False,
             results_dir: str = RESULTS_DIR) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    os.makedirs(results_dir, exist_ok=True)
    out_path = os.path.join(results_dir, cell + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = dict(cell=cell, arch=arch, shape=shape_name, mesh=mesh_name,
                  status="skipped", reason=None)
    if not shape_applicable(cfg, shape):
        record["reason"] = ("long_500k needs sub-quadratic attention; "
                            f"{arch} is full-attention (DESIGN.md "
                            "§Arch-applicability)")
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        return record

    t0 = time.time()
    try:
        lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod,
                                               q_chunk=q_chunk)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_d = {k: getattr(mem, k) for k in dir(mem)
                 if k.endswith("_bytes") or k.endswith("bytes")}
        mem_d = {k: int(v) for k, v in mem_d.items() if isinstance(v, int)}
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        chips = int(mesh.devices.size)
        raw_roof = build_roofline(arch, shape_name, mesh_name, chips,
                                  cost, mem_d, hlo,
                                  model_flops_for(cfg, shape))
        # calibrated (scan-trip-count-corrected) costs — see calibrated_costs
        t_cal = time.time()
        cal = calibrated_costs(arch, shape_name, multi_pod, q_chunk)
        cal_cost = {"flops": cal["corrected"]["flops"],
                    "bytes accessed": cal["corrected"]["bytes"]}
        roof = build_roofline(arch, shape_name, mesh_name, chips,
                              cal_cost, mem_d, "",
                              model_flops_for(cfg, shape))
        roof.coll_bytes = cal["corrected"]["coll"]
        roof.coll_breakdown = cal["breakdown"]
        roof.coll_ops = raw_roof.coll_ops
        record |= dict(
            status="ok",
            t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
            t_calibrate_s=round(time.time() - t_cal, 1),
            memory=mem_d,
            cost=dict(flops=float(cost.get("flops", 0.0)),
                      bytes_accessed=float(cost.get("bytes accessed", 0.0))),
            roofline=roof.to_dict(),
            roofline_raw=raw_roof.to_dict(),
            calibration=dict(method=cal["method"],
                             points=cal.get("points", [])),
            params_total=cfg.param_count(),
            params_active=cfg.active_param_count(),
        )
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        record |= dict(status="error", reason=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, q_chunk=args.q_chunk,
                             force=args.force)
                status = r["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={r['t_compile_s']}s "
                             f"bottleneck={r['roofline']['bottleneck']}")
                elif status == "error":
                    extra = f" {r['reason'][:120]}"
                print(f"[{status:7s}] {r['cell']}{extra}", flush=True)


if __name__ == "__main__":
    main()
