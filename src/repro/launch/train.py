"""End-to-end training driver.

Runs a real training loop on the local device set (CPU smoke / TPU slice):
data pipeline -> sharded train_step (microbatched, AdamW/ZeRO) ->
checkpointing via the fault-tolerance supervisor. The production launch on
a pod uses the identical code path with make_production_mesh().

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 100 --global-batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, restore_into
from repro.configs import get_config, reduced_config
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import get_model
from repro.sharding.rules import ShardingRules, active_rules, default_rules
from repro.train import AdamWConfig, init_state, make_train_step


def run_training(cfg, *, steps: int, global_batch: int, seq_len: int,
                 lr: float = 3e-4, num_microbatches: int = 1,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 50, mesh=None, q_chunk: int = 512,
                 log_every: int = 10, seed: int = 0):
    mesh = mesh or make_local_mesh()
    rules = ShardingRules(mesh, default_rules("pod" in mesh.shape))
    model = get_model(cfg)

    key = jax.random.PRNGKey(seed)
    with active_rules(rules):
        params, axes = model.init_params(cfg, key)
        adam = AdamWConfig(lr=lr)
        opt_state = init_state(params, adam)
        step_fn = jax.jit(make_train_step(
            cfg, model, adam, num_microbatches=num_microbatches,
            loss_kwargs=dict(q_chunk=q_chunk)))

    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=seq_len,
                                      global_batch=global_batch, seed=seed))
    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        flat, manifest = ckpt.restore()
        state = restore_into(dict(params=params, opt=opt_state), flat)
        params, opt_state = state["params"], state["opt"]
        start_step = manifest["step"]
        print(f"[train] restored step {start_step}")

    def make_batch(i):
        b = data.batch_at(i)
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = jnp.zeros(
                (global_batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            extra["img_embeds"] = jnp.zeros(
                (global_batch, cfg.num_image_tokens, cfg.d_model),
                jnp.bfloat16)
        return dict(tokens=jnp.asarray(b["tokens"]),
                    labels=jnp.asarray(b["labels"]), **extra)

    losses = []
    t0 = time.time()
    with active_rules(rules):
        for i in range(start_step, steps):
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 make_batch(i))
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0:
                dt = (time.time() - t0) / max(len(losses), 1)
                print(f"[train] step {i+1:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/step", flush=True)
            if ckpt and (i + 1) % checkpoint_every == 0:
                ckpt.save(i + 1, dict(params=params, opt=opt_state),
                          blocking=False)
    if ckpt:
        ckpt.save(steps, dict(params=params, opt=opt_state), blocking=True)
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh else None
    _, _, losses = run_training(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr,
        num_microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir, mesh=mesh, q_chunk=64)
    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
