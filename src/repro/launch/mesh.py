"""Production mesh definitions.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax import).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips (one pod); 2x16x16 = 512 chips (two pods).

    When the process exposes more devices than the mesh needs (the dry-run
    boots 512 host devices for both meshes), the first `n` are used.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(f"need {need} devices, have {len(devs)} — "
                           "set XLA_FLAGS=--xla_force_host_platform_device_count")
    return Mesh(np.array(devs[:need]).reshape(shape), axes)


def make_local_mesh() -> Mesh:
    """Degenerate 1x1 mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
