"""Jaxpr-level engine lints: RNG-key discipline, dtype funnels, schema.

These passes walk the *same* closed jaxprs the CONGEST auditor traces
(`analysis.congest` calls them on each engine stage program), so the
properties they certify hold for the exact programs the runtime executes:

  rng_lint     — no PRNG key is consumed by two `jax.random` equations.
                 Key reuse silently correlates draws (walk steps that
                 should be independent share randomness), and it breaks
                 the elastic-resume contract: a stage whose draws depend
                 on *how often* a key was touched cannot be replayed.
                 Stages that consume no RNG at all are flagged so the
                 resume classifier can certify them bit-exact.

  dtype_lint   — integer counts funneled through float ops. A float32
                 represents integers exactly only up to 2^24; an engine
                 whose declared `count_bound` exceeds the target float's
                 exact range must not route counts through it (the
                 truncation is silent — counts just stop incrementing).
                 Weak-type int->float promotions are surfaced as notes.

  schema_lint  — elastic-schema completeness: every device buffer of a
                 `runtime.StagedState` stage is covered by exactly one
                 `checkpoint.LayoutSpec` entry, and no spec dangles.
                 An uncovered buffer resumes as garbage on a resized
                 mesh; a dangling spec means the schema drifted.

All three return `LintFinding` rows; `severity == "violation"` fails the
strict CI gate, `"note"` is informational. The walkers recurse through
pjit / shard_map / scan / while / cond sub-jaxprs, mapping sub-jaxpr
invars back to the caller's vars so key lineages survive the descent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "LintFinding", "iter_subjaxprs", "rng_lint", "dtype_lint",
    "schema_lint", "classify_resume",
]


@dataclasses.dataclass(frozen=True)
class LintFinding:
    lint: str       # "rng" | "dtype" | "schema"
    severity: str   # "violation" | "note"
    where: str      # program / jaxpr path the finding anchors to
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# shared jaxpr plumbing
# ---------------------------------------------------------------------------

def _is_literal(v: Any) -> bool:
    return hasattr(v, "val")


def _unclose(j: Any) -> Any:
    """ClosedJaxpr -> Jaxpr (raw Jaxprs pass through)."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def iter_subjaxprs(eqn: Any) -> Iterator[Tuple[Any, List[Any], int]]:
    """Yield `(inner_jaxpr, outer_invars, trip_mult)` for each sub-jaxpr.

    `outer_invars[i]` is the caller-side var feeding `inner.invars[i]`
    (None where the positions don't line up). `trip_mult` is how many
    times one execution of the equation runs the body: scan length for
    scans, 0 for while bodies (statically unbounded), 1 otherwise — the
    congest auditor multiplies nested trip counts to detect collectives
    inside loops.
    """
    prim = eqn.primitive.name
    params = eqn.params
    if prim == "cond":
        for br in params["branches"]:
            inner = _unclose(br)
            yield inner, list(eqn.invars[1:]), 1
        return
    if prim == "while":
        for k in ("cond_jaxpr", "body_jaxpr"):
            inner = _unclose(params[k])
            yield inner, list(eqn.invars), 0
        return
    if prim == "scan":
        yield _unclose(params["jaxpr"]), list(eqn.invars), int(params.get("length", 1))
        return
    for k in ("jaxpr", "call_jaxpr"):
        if k in params:
            yield _unclose(params[k]), list(eqn.invars), 1
            return
    # anything else that stashes a (Closed)Jaxpr in params (custom_* etc.)
    for v in params.values():
        if hasattr(v, "eqns") or (hasattr(v, "jaxpr") and hasattr(_unclose(v), "eqns")):
            yield _unclose(v), list(eqn.invars), 1


def _map_invars(inner: Any, outer_invars: List[Any],
                kidmap: Dict[Any, Any]) -> Dict[Any, Any]:
    """Carry key lineage ids from caller vars into a sub-jaxpr's invars."""
    inner_map: Dict[Any, Any] = {}
    for iv, ov in zip(inner.invars, outer_invars):
        if ov is None or _is_literal(ov):
            continue
        kid = kidmap.get(ov)
        if kid is not None:
            inner_map[iv] = kid
    return inner_map


# ---------------------------------------------------------------------------
# RNG-key discipline
# ---------------------------------------------------------------------------

# equations that CONSUME a key: two of these on the same lineage = reuse.
# (`random_fold_in` is NOT a consumer — folding distinct data into one key
# is the counter-based derivation idiom; each fold_in equation starts its
# own lineage below. Folding the SAME value twice is statically
# indistinguishable and out of scope.)
_RNG_CONSUMERS = frozenset({
    "random_bits", "random_split", "random_gamma",
})
# shape/representation changes that keep the lineage intact.
_RNG_PASSTHROUGH = frozenset({
    "random_wrap", "random_unwrap", "squeeze", "reshape",
    "broadcast_in_dim", "convert_element_type", "copy",
})
# ops that DERIVE an independent key from a parent: indexing one row of a
# random_split result, or folding data in — each equation is its own
# lineage.
_RNG_INDEXERS = frozenset({"slice", "dynamic_slice", "gather",
                           "random_fold_in"})


def _keylike(aval: Any) -> bool:
    try:
        dtype = aval.dtype
    except Exception:
        return False
    if "key" in str(dtype):          # typed PRNG key arrays (key<fry> etc.)
        return True
    try:
        return (np.issubdtype(dtype, np.unsignedinteger)
                and getattr(aval, "ndim", 0) >= 1
                and aval.shape[-1] == 2)
    except Exception:
        return False


def _rng_walk(jaxpr: Any, kidmap: Dict[Any, Any], counts: Dict[Any, int],
              sites: Dict[Any, List[str]], path: str) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _RNG_CONSUMERS:
            v = eqn.invars[0]
            if not _is_literal(v):
                kid = kidmap.get(v)
                if kid is None and _keylike(v.aval):
                    kid = ("anon", id(v))
                    kidmap[v] = kid
                if kid is not None:
                    counts[kid] = counts.get(kid, 0) + 1
                    sites.setdefault(kid, []).append(f"{path}{prim}")
            # split / fold_in derive fresh independent lineages
            for ov in eqn.outvars:
                kidmap[ov] = ("derived", id(eqn))
            continue
        subs = list(iter_subjaxprs(eqn))
        if subs:
            if prim == "cond":
                # branches are exclusive: key use in both arms of one cond
                # is NOT reuse — merge consumption counts by max.
                merged = dict(counts)
                for inner, outer_invars, _ in subs:
                    local = dict(counts)
                    _rng_walk(inner, _map_invars(inner, outer_invars, kidmap),
                              local, sites, f"{path}{prim}/")
                    for k, c in local.items():
                        merged[k] = max(merged.get(k, 0), c)
                counts.clear()
                counts.update(merged)
            else:
                for inner, outer_invars, _ in subs:
                    _rng_walk(inner, _map_invars(inner, outer_invars, kidmap),
                              counts, sites, f"{path}{prim}/")
            continue
        if prim in _RNG_INDEXERS:
            v = eqn.invars[0]
            if not _is_literal(v):
                kid = kidmap.get(v)
                if kid is not None:
                    start = tuple(eqn.params.get("start_indices", ())) or id(eqn)
                    kidmap[eqn.outvars[0]] = (kid, prim, start)
            continue
        if prim in _RNG_PASSTHROUGH:
            v = eqn.invars[0]
            if not _is_literal(v):
                kid = kidmap.get(v)
                if kid is not None:
                    kidmap[eqn.outvars[0]] = kid


def rng_lint(closed_jaxpr: Any, *, where: str = "") -> Tuple[List[LintFinding], int]:
    """Check PRNG-key discipline on one traced program.

    Returns `(findings, consumed)`: one violation per key lineage consumed
    by more than one `jax.random` equation, plus the total number of RNG
    consumptions — 0 means the program is RNG-free (and therefore
    trivially bit-exact under elastic resume).
    """
    jaxpr = _unclose(closed_jaxpr)
    kidmap: Dict[Any, Any] = {}
    for i, v in enumerate(jaxpr.invars):
        if _keylike(v.aval):
            kidmap[v] = ("arg", i)
    counts: Dict[Any, int] = {}
    sites: Dict[Any, List[str]] = {}
    _rng_walk(jaxpr, kidmap, counts, sites, "")
    findings = []
    for kid, c in counts.items():
        if c > 1:
            findings.append(LintFinding(
                lint="rng", severity="violation", where=where,
                message=(f"key lineage {kid!r} consumed {c} times "
                         f"(at {', '.join(sites[kid])}) — correlated draws; "
                         f"derive sub-keys with split/fold_in instead")))
    return findings, sum(counts.values())


# ---------------------------------------------------------------------------
# dtype audit
# ---------------------------------------------------------------------------

_MANTISSA_BITS = {"float64": 53, "float32": 24, "float16": 11, "bfloat16": 8}


def _dtype_walk(jaxpr: Any, count_bound: Optional[int], where: str,
                path: str, out: List[LintFinding]) -> None:
    for eqn in jaxpr.eqns:
        subs = list(iter_subjaxprs(eqn))
        if subs:
            for inner, _, _ in subs:
                _dtype_walk(inner, count_bound, where,
                            f"{path}{eqn.primitive.name}/", out)
            continue
        if eqn.primitive.name != "convert_element_type":
            continue
        src = getattr(eqn.invars[0], "aval", None)
        new_dtype = eqn.params.get("new_dtype")
        if src is None or new_dtype is None:
            continue
        try:
            src_int = np.issubdtype(src.dtype, np.integer)
            dst_float = np.issubdtype(np.dtype(new_dtype), np.floating)
        except Exception:
            continue
        if not (src_int and dst_float):
            continue
        mant = _MANTISSA_BITS.get(np.dtype(new_dtype).name, 53)
        if count_bound is not None and count_bound > (1 << mant):
            out.append(LintFinding(
                lint="dtype", severity="violation", where=where,
                message=(f"{path}: {src.dtype}->{np.dtype(new_dtype).name} "
                         f"funnel with declared count_bound={count_bound} "
                         f"> 2^{mant} — counts above 2^{mant} truncate "
                         f"silently; widen or use an exact integer path")))
        elif getattr(src, "weak_type", False):
            out.append(LintFinding(
                lint="dtype", severity="note", where=where,
                message=(f"{path}: weak-typed {src.dtype} promoted to "
                         f"{np.dtype(new_dtype).name} (implicit promotion)")))


def dtype_lint(closed_jaxpr: Any, *, count_bound: Optional[int] = None,
               where: str = "") -> List[LintFinding]:
    """Flag integer->float funnels whose declared count bound exceeds the
    target float's exact-integer range (2^mantissa)."""
    out: List[LintFinding] = []
    _dtype_walk(_unclose(closed_jaxpr), count_bound, where, "", out)
    return out


# ---------------------------------------------------------------------------
# elastic-schema completeness
# ---------------------------------------------------------------------------

def schema_lint(stage_arrays: Dict[str, Tuple[str, ...]],
                layouts: Dict[str, Dict[str, Any]]) -> List[LintFinding]:
    """Every `StagedState` device buffer covered by exactly one
    `LayoutSpec`, and no spec without a buffer."""
    out: List[LintFinding] = []
    for stage, arrays in stage_arrays.items():
        specs = layouts.get(stage)
        if specs is None:
            out.append(LintFinding(
                lint="schema", severity="violation", where=stage,
                message=f"stage '{stage}' has no LayoutSpec schema at all"))
            continue
        for name in sorted(set(arrays) - set(specs)):
            out.append(LintFinding(
                lint="schema", severity="violation", where=stage,
                message=(f"device buffer '{name}' of stage '{stage}' has no "
                         f"LayoutSpec — it would resume as garbage on a "
                         f"resized mesh")))
        for name in sorted(set(specs) - set(arrays)):
            out.append(LintFinding(
                lint="schema", severity="violation", where=stage,
                message=(f"LayoutSpec '{name}' of stage '{stage}' covers no "
                         f"device buffer — dangling schema entry")))
    return out


# ---------------------------------------------------------------------------
# elastic-resume classification (consumes rng_lint + schema info)
# ---------------------------------------------------------------------------

def classify_resume(stage: str, rng_consumed: int,
                    layouts_for_stage: Dict[str, Any]
                    ) -> Tuple[str, List[LintFinding]]:
    """Classify a stage's elastic-resume guarantee from its RNG usage and
    how its key buffers are laid out.

      no RNG consumed                  -> bit-exact (RNG-free)
      RNG + all keys replicated        -> bit-exact (round-replicated key:
                                          the same per-round key is
                                          re-derived on any mesh size)
      RNG + per-shard key buffers      -> statistical (per-shard keys are
                                          re-derived on resize, so resumed
                                          draws differ bit-for-bit but not
                                          in distribution)
      RNG but no key buffer in schema  -> violation (the stage draws from
                                          state the checkpoint never saves)
    """
    key_kinds = sorted({getattr(s, "kind", "?")
                        for s in (layouts_for_stage or {}).values()
                        if getattr(s, "kind", "") in ("key", "replicated_key")})
    if rng_consumed == 0:
        return "bit-exact (RNG-free)", []
    if not key_kinds:
        return "unresumable", [LintFinding(
            lint="rng", severity="violation", where=stage,
            message=(f"stage '{stage}' consumes RNG but its layout schema "
                     f"holds no key buffer — resumed runs would replay "
                     f"with lost randomness"))]
    if key_kinds == ["replicated_key"]:
        return "bit-exact (replicated key)", []
    return "statistical (per-shard keys re-derived on resize)", []
