"""CONGEST auditor: jaxpr-level wire-budget verification for the engines.

The paper's efficiency theorems are statements about per-round wire: in
CONGEST every edge carries B = polylog(n) bits per round, and Lemma 1 is
what makes the walk phases fit — counts of anonymous walks are exchanged,
so the payload is bounded by *distinct vertices*, never by the walk
multiplicity W. The engines encode that bound in their lane sizing; this
module machine-checks it against the programs the runtime actually
executes, with no instrumentation of the hot path:

  1. Each engine's `audit_spec(graph, mesh)` rebuilds its jitted stage
     programs through the SAME memoized step makers (identical static
     arguments => identical cache keys => the traced jaxpr IS the runtime
     program) and declares one `ExchangeSite` per expected all_to_all.
  2. `trace_program` closes each program over ShapeDtypeStructs and
     `collect_collectives` walks the jaxpr — recursing through pjit /
     shard_map / scan / while / cond sub-jaxprs — to find every
     collective with its per-shard payload bytes (inside shard_map the
     avals are already per-shard) and loop trip multiplier.
  3. The budget checks: every traced all_to_all matches a declared site,
     runs exactly once per program call (no collective hiding in a loop),
     moves exactly `lane_entries * entry_nbytes` bytes, and its lane count
     fits the declared W-free budget. psums are control-plane and must
     stay under `PSUM_CONTROL_BYTES`; ppermute / all_gather are not used
     by any engine and tracing one is a violation outright.
  4. W-independence: the spec is rebuilt at 2x the walk multiplicity and
     every matched site must declare the identical budget (walk-class
     lanes are auditor-pinned at n_loc, so their checked capacity is
     W-free too).
  5. Telemetry cross-check: each engine runs on a fixture graph and its
     runtime byte counters must equal its runtime entry counters times
     the declared per-entry width — the static widths and the
     `entry_nbytes`-derived runtime accounting agree exactly.

The lint passes (`analysis.lint`: RNG-key discipline + elastic-resume
classification, int->float count funnels, elastic-schema completeness)
run over the same traces, so one trace per program serves every check.
`scripts/audit_engines.py` and `launch --audit` drive `audit_all_engines`
and render `format_wire_table` / AUDIT.json; CI gates on zero violations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.lint import (LintFinding, classify_resume, dtype_lint,
                                 iter_subjaxprs, rng_lint, schema_lint)
from repro.core.accounting import EngineAuditSpec, StageProgram

__all__ = [
    "PSUM_CONTROL_BYTES", "CollectiveSite", "AuditViolation",
    "trace_program", "collect_collectives", "audit_program",
    "audit_engine_spec", "check_w_independence", "audit_all_engines",
    "format_wire_table",
]

# psums move O(1) scalars / tiny per-bucket vectors of control state
# (active counters, conservation tripwires, occupancy) — bounded by a
# constant, not by n or W.
PSUM_CONTROL_BYTES = 256

_A2A_PRIMS = frozenset({"all_to_all"})
_CONTROL_PRIMS = frozenset({"psum"})
_UNEXPECTED_PRIMS = frozenset({"ppermute", "all_gather"})
_ALL_PRIMS = _A2A_PRIMS | _CONTROL_PRIMS | _UNEXPECTED_PRIMS


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective equation found in a traced program."""

    prim: str            # all_to_all | psum | ppermute | all_gather
    path: str            # jaxpr path, e.g. "pjit/shard_map/all_to_all"
    payload_bytes: int   # per-shard operand bytes (avals inside shard_map
                         # are per-shard already)
    trip_mult: int       # product of enclosing loop trip counts (scan
                         # length; 0 under a while body)


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    engine: str
    kind: str            # "budget/..." | "lint/rng" | "lint/dtype" | ...
    where: str           # "stage/program" (or stage for schema findings)
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _aval_nbytes(aval: Any) -> int:
    try:
        size = int(np.prod(aval.shape, dtype=np.int64)) if aval.shape else 1
        return size * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def trace_program(fn: Any, example_args: Tuple[Any, ...]) -> Any:
    """Close a jitted stage program over its example ShapeDtypeStructs."""
    return jax.make_jaxpr(fn)(*example_args)


def collect_collectives(jaxpr: Any, path: str = "", mult: int = 1,
                        out: Optional[List[CollectiveSite]] = None
                        ) -> List[CollectiveSite]:
    """Every collective equation reachable from `jaxpr`, in program order,
    recursing through pjit / shard_map / scan / while / cond."""
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _ALL_PRIMS:
            payload = sum(_aval_nbytes(v.aval) for v in eqn.invars
                          if hasattr(v, "aval"))
            out.append(CollectiveSite(prim=prim, path=f"{path}{prim}",
                                      payload_bytes=payload, trip_mult=mult))
            continue
        for inner, _, m in iter_subjaxprs(eqn):
            collect_collectives(inner, f"{path}{prim}/", mult * m, out)
    return out


def audit_program(prog: StageProgram, engine: str
                  ) -> Tuple[Any, List[CollectiveSite], List[AuditViolation]]:
    """Trace one stage program and run the wire-budget checks against its
    declared `ExchangeSite`s. Returns (closed_jaxpr, collectives, violations)
    so the lint passes can reuse the trace."""
    where = f"{prog.stage}/{prog.program}"
    cj = trace_program(prog.fn, prog.example_args)
    colls = collect_collectives(cj.jaxpr)
    violations: List[AuditViolation] = []

    a2a = [c for c in colls if c.prim in _A2A_PRIMS]
    if len(a2a) != len(prog.sites):
        violations.append(AuditViolation(
            engine=engine, kind="budget/site-count", where=where,
            message=(f"traced {len(a2a)} all_to_all launches but "
                     f"{len(prog.sites)} declared "
                     f"({[s.site for s in prog.sites]})")))
    for c, site in zip(a2a, prog.sites):
        if c.trip_mult != 1:
            violations.append(AuditViolation(
                engine=engine, kind="budget/loop", where=where,
                message=(f"site '{site.site}' ({c.path}) executes with loop "
                         f"multiplier {c.trip_mult} — a per-round budget "
                         f"only bounds a collective that runs once per "
                         f"program call")))
        expected = site.lane_entries * site.entry_nbytes
        if c.payload_bytes != expected:
            violations.append(AuditViolation(
                engine=engine, kind="budget/payload", where=where,
                message=(f"site '{site.site}' compiled payload is "
                         f"{c.payload_bytes} B but the declaration says "
                         f"{site.lane_entries} lanes x {site.entry_nbytes} B "
                         f"= {expected} B")))
        if site.lane_entries > site.budget_entries:
            violations.append(AuditViolation(
                engine=engine, kind="budget/exceeded", where=where,
                message=(f"site '{site.site}' lane capacity "
                         f"{site.lane_entries} exceeds its W-free budget "
                         f"{site.budget_entries} ({site.budget_formula})")))
    for c in colls:
        if c.prim in _CONTROL_PRIMS and c.payload_bytes > PSUM_CONTROL_BYTES:
            violations.append(AuditViolation(
                engine=engine, kind="budget/psum", where=where,
                message=(f"{c.path} moves {c.payload_bytes} B — control "
                         f"psums must stay under {PSUM_CONTROL_BYTES} B "
                         f"(data belongs on the counted all_to_all wire)")))
        elif c.prim in _UNEXPECTED_PRIMS:
            violations.append(AuditViolation(
                engine=engine, kind="budget/unexpected-collective",
                where=where,
                message=(f"{c.path}: no engine declares {c.prim} — all data "
                         f"motion must go through declared all_to_all "
                         f"sites")))
    return cj, colls, violations


def _lint_to_violation(engine: str, f: LintFinding) -> AuditViolation:
    return AuditViolation(engine=engine, kind=f"lint/{f.lint}",
                          where=f.where, message=f.message)


def audit_engine_spec(spec: EngineAuditSpec) -> Dict[str, Any]:
    """Full static audit of one engine: budget checks + lints + resume
    classification, from a single trace of each stage program."""
    violations: List[AuditViolation] = []
    notes: List[dict] = []
    site_rows: List[dict] = []
    rng_by_stage: Dict[str, int] = {}
    psum_sites = 0
    psum_max = 0

    for prog in spec.programs:
        where = f"{prog.stage}/{prog.program}"
        cj, colls, vs = audit_program(prog, spec.engine)
        violations.extend(vs)

        rng_findings, consumed = rng_lint(cj, where=where)
        violations.extend(_lint_to_violation(spec.engine, f)
                          for f in rng_findings)
        rng_by_stage[prog.stage] = rng_by_stage.get(prog.stage, 0) + consumed
        for f in dtype_lint(cj, count_bound=prog.count_bound, where=where):
            if f.severity == "violation":
                violations.append(_lint_to_violation(spec.engine, f))
            else:
                notes.append(f.to_dict())

        a2a = [c for c in colls if c.prim in _A2A_PRIMS]
        for c, site in zip(a2a, prog.sites):
            site_rows.append(dict(
                stage=prog.stage, program=prog.program, site=site.site,
                entry_nbytes=site.entry_nbytes,
                lane_entries=site.lane_entries,
                budget_entries=site.budget_entries,
                capacity_bytes=site.capacity_bytes,
                budget_bytes=site.budget_bytes,
                traced_payload_bytes=c.payload_bytes,
                wire_class=site.wire_class,
                budget_formula=site.budget_formula, note=site.note))
        for c in colls:
            if c.prim in _CONTROL_PRIMS:
                psum_sites += 1
                psum_max = max(psum_max, c.payload_bytes)

    violations.extend(_lint_to_violation(spec.engine, f)
                      for f in schema_lint(spec.stage_arrays, spec.layouts))

    resume: Dict[str, str] = {}
    for stage in spec.stage_arrays:
        cls, findings = classify_resume(stage, rng_by_stage.get(stage, 0),
                                        spec.layouts.get(stage, {}))
        resume[stage] = cls
        violations.extend(_lint_to_violation(spec.engine, f)
                          for f in findings)

    return dict(
        engine=spec.engine, sites=site_rows,
        psum_sites=psum_sites, psum_max_bytes=psum_max,
        rng_consumed_by_stage=rng_by_stage, resume=resume, notes=notes,
        violations=[v.to_dict() for v in violations],
        meta={k: (int(v) if isinstance(v, (np.integer,)) else v)
              for k, v in spec.meta.items()})


def check_w_independence(spec_lo: EngineAuditSpec, spec_hi: EngineAuditSpec
                         ) -> List[AuditViolation]:
    """Rebuild the spec at double the walk multiplicity: every matched site
    must declare the identical W-free budget (lane capacities may grow
    toward the budget — e.g. the phase-1 reply lane saturates at
    n_loc*(max_deg+1) — but must stay within it at both multiplicities)."""
    violations: List[AuditViolation] = []
    lo = [(p.stage, p.program, s) for p in spec_lo.programs for s in p.sites]
    hi = [(p.stage, p.program, s) for p in spec_hi.programs for s in p.sites]
    if [(st, pr, s.site) for st, pr, s in lo] != \
       [(st, pr, s.site) for st, pr, s in hi]:
        violations.append(AuditViolation(
            engine=spec_lo.engine, kind="budget/w-dependence", where="*",
            message="site list changes with walk multiplicity"))
        return violations
    for (stage, program, a), (_, _, b) in zip(lo, hi):
        where = f"{stage}/{program}"
        if (a.entry_nbytes, a.budget_entries, a.budget_formula,
                a.wire_class) != (b.entry_nbytes, b.budget_entries,
                                  b.budget_formula, b.wire_class):
            violations.append(AuditViolation(
                engine=spec_lo.engine, kind="budget/w-dependence",
                where=where,
                message=(f"site '{a.site}' budget changes with walk "
                         f"multiplicity: {a.budget_entries} x "
                         f"{a.entry_nbytes} B -> {b.budget_entries} x "
                         f"{b.entry_nbytes} B — budgets must depend on the "
                         f"partition and polylog(n) only, never on W")))
        if b.lane_entries > b.budget_entries:
            violations.append(AuditViolation(
                engine=spec_lo.engine, kind="budget/w-dependence",
                where=where,
                message=(f"site '{a.site}' lane capacity grows past its "
                         f"budget at 2x walks: {b.lane_entries} > "
                         f"{b.budget_entries}")))
    return violations


# ---------------------------------------------------------------------------
# runtime telemetry cross-check — static widths vs entry_nbytes counters
# ---------------------------------------------------------------------------

def _check(name: str, runtime_bytes: int, entries: int, width: int) -> dict:
    return dict(name=name, runtime_bytes=int(runtime_bytes),
                entries=int(entries), entry_nbytes=int(width),
                expected_bytes=int(entries) * int(width),
                ok=int(runtime_bytes) == int(entries) * int(width))


def _site_widths(spec: EngineAuditSpec) -> Dict[str, int]:
    return {s.site: s.entry_nbytes for p in spec.programs for s in p.sites}


def _telemetry_walks(graph, mesh, spec, eps, K, use_pallas):
    from repro.core.distributed import distributed_pagerank
    res = distributed_pagerank(graph, eps, walks_per_node=K,
                               key=jax.random.PRNGKey(0), mesh=mesh,
                               use_pallas=use_pallas)
    w = _site_widths(spec)["route"]
    return [_check("route", res.a2a_bytes_total, res.a2a_entries_total, w)]


def _telemetry_counts(graph, mesh, spec, eps, K, use_pallas):
    from repro.core.distributed_counts import distributed_pagerank_counts
    res = distributed_pagerank_counts(graph, eps, walks_per_node=K,
                                      key=jax.random.PRNGKey(0), mesh=mesh,
                                      use_pallas=use_pallas)
    w = _site_widths(spec)["counts"]
    return [_check("counts", res.a2a_bytes_total, res.a2a_entries_total, w)]


def _telemetry_three_phase(graph, mesh, spec, eps, K, use_pallas, *,
                           directed: bool):
    if directed:
        from repro.core.distributed_directed import \
            distributed_directed_pagerank as run
    else:
        from repro.core.distributed_improved import \
            distributed_improved_pagerank as run
    res = run(graph, eps, walks_per_node=K, key=jax.random.PRNGKey(0),
              mesh=mesh, use_pallas=use_pallas)
    w = _site_widths(spec)
    wire, ent = res.a2a_bytes_by_phase, res.a2a_entries_by_site
    checks = [
        dict(name="phase1", runtime_bytes=int(wire.get("phase1", 0)),
             entries=int(ent.get("phase1_req", 0) + ent.get("phase1_rep", 0)),
             entry_nbytes=0,
             expected_bytes=(ent.get("phase1_req", 0) * w["phase1_req"]
                             + ent.get("phase1_rep", 0) * w["phase1_rep"]),
             ok=int(wire.get("phase1", 0)) ==
                (ent.get("phase1_req", 0) * w["phase1_req"]
                 + ent.get("phase1_rep", 0) * w["phase1_rep"])),
        _check("phase2", wire.get("phase2", 0), ent.get("phase2", 0),
               w["phase2"]),
        _check("phase3", wire.get("phase3", 0), ent.get("phase3", 0),
               w["phase3"]),
        _check("tail", wire.get("tail", 0), ent.get("tail", 0), w["tail"]),
        dict(name="report", runtime_bytes=int(wire.get("report", 0)),
             entries=0, entry_nbytes=0, expected_bytes=0,
             ok=int(wire.get("report", 0)) == 0),
    ]
    return checks


def _telemetry_ppr(graph, mesh, spec, eps, K, use_pallas):
    from repro.core.personalized_batch import batched_personalized_pagerank
    res = batched_personalized_pagerank(
        graph, eps, queries=[([0], None), ([1, 2], None)],
        walks_per_query=spec.meta["walks_per_query"],
        key=jax.random.PRNGKey(1), mesh=mesh, use_pallas=use_pallas)
    w = _site_widths(spec)["ppr"]
    return [_check("ppr", res.a2a_bytes, res.a2a_entries, w)]


# ---------------------------------------------------------------------------
# the full audit
# ---------------------------------------------------------------------------

ENGINES = ("walks", "counts", "improved", "directed", "ppr")


def _fixture_for(engine: str):
    from repro.graphs import directed_web, erdos_renyi
    if engine == "directed":
        return directed_web(96, 5.0, seed=3), "directed_web(96, 5.0, seed=3)"
    return erdos_renyi(96, 5.0, seed=1), "erdos_renyi(96, 5.0, seed=1)"


def _spec_for(engine: str, graph, mesh, *, eps: float, K: int,
              use_pallas: bool) -> EngineAuditSpec:
    if engine == "walks":
        from repro.core.distributed import audit_spec
        return audit_spec(graph, mesh, eps=eps, walks_per_node=K,
                          use_pallas=use_pallas)
    if engine == "counts":
        from repro.core.distributed_counts import audit_spec
        return audit_spec(graph, mesh, eps=eps, walks_per_node=K,
                          use_pallas=use_pallas)
    if engine == "improved":
        from repro.core.distributed_improved import audit_spec
        return audit_spec(graph, mesh, eps=eps, walks_per_node=K,
                          use_pallas=use_pallas)
    if engine == "directed":
        from repro.core.distributed_directed import audit_spec
        return audit_spec(graph, mesh, eps=eps, walks_per_node=K,
                          use_pallas=use_pallas)
    if engine == "ppr":
        from repro.core.personalized_batch import audit_spec
        return audit_spec(graph, mesh, eps=eps, walks_per_query=4 * K,
                          use_pallas=use_pallas)
    raise ValueError(f"unknown engine '{engine}' (one of {ENGINES})")


def audit_all_engines(mesh=None, *, use_pallas: bool = False,
                      run_telemetry: bool = True, eps: float = 0.2,
                      walks_per_node: int = 2,
                      engines: Optional[Tuple[str, ...]] = None
                      ) -> Dict[str, Any]:
    """Audit every distributed engine; returns the AUDIT.json dict.

    Static checks trace the engines' own memoized stage programs; with
    `run_telemetry` the engines also execute on small fixture graphs and
    their runtime byte counters are checked against the runtime entry
    counters times the declared per-entry widths.
    """
    from jax.sharding import Mesh

    from repro.core.distributed import AXIS
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    shards = int(mesh.devices.size)
    K = walks_per_node
    report: Dict[str, Any] = dict(devices=shards, use_pallas=use_pallas,
                                  eps=eps, walks_per_node=K, engines={})
    total = 0
    for engine in (engines or ENGINES):
        graph, fixture = _fixture_for(engine)
        spec = _spec_for(engine, graph, mesh, eps=eps, K=K,
                         use_pallas=use_pallas)
        entry = audit_engine_spec(spec)
        entry["fixture"] = fixture

        spec_hi = _spec_for(engine, graph, mesh, eps=eps, K=2 * K,
                            use_pallas=use_pallas)
        w_violations = check_w_independence(spec, spec_hi)
        entry["w_independent"] = not w_violations
        entry["violations"].extend(v.to_dict() for v in w_violations)

        if run_telemetry:
            if engine == "walks":
                checks = _telemetry_walks(graph, mesh, spec, eps, K,
                                          use_pallas)
            elif engine == "counts":
                checks = _telemetry_counts(graph, mesh, spec, eps, K,
                                           use_pallas)
            elif engine in ("improved", "directed"):
                checks = _telemetry_three_phase(
                    graph, mesh, spec, eps, K, use_pallas,
                    directed=engine == "directed")
            else:
                checks = _telemetry_ppr(graph, mesh, spec, eps, K,
                                        use_pallas)
            entry["telemetry"] = dict(checks=checks,
                                      ok=all(c["ok"] for c in checks))
            for c in checks:
                if not c["ok"]:
                    entry["violations"].append(AuditViolation(
                        engine=engine, kind="telemetry/mismatch",
                        where=c["name"],
                        message=(f"runtime wire {c['runtime_bytes']} B != "
                                 f"{c['entries']} entries x declared width "
                                 f"(expected {c['expected_bytes']} B)")
                    ).to_dict())
        total += len(entry["violations"])
        report["engines"][engine] = entry
    report["violations_total"] = total
    report["ok"] = total == 0
    return report


def format_wire_table(report: Dict[str, Any]) -> str:
    """Render the per-engine wire-budget table for --audit / CI logs."""
    hdr = (f"{'engine':<9} {'stage/site':<22} {'B/ent':>5} {'lanes':>7} "
           f"{'budget':>7} {'cap B':>8} {'traced B':>8} {'class':<6} "
           f"{'resume':<16}")
    lines = [f"CONGEST wire audit — {report['devices']} shards, "
             f"eps={report['eps']}, K={report['walks_per_node']}",
             hdr, "-" * len(hdr)]
    for name, e in report["engines"].items():
        for row in e["sites"]:
            resume = e["resume"].get(row["stage"], "?").split(" (")[0]
            lines.append(
                f"{name:<9} {row['stage'] + '/' + row['site']:<22} "
                f"{row['entry_nbytes']:>5} {row['lane_entries']:>7} "
                f"{row['budget_entries']:>7} {row['capacity_bytes']:>8} "
                f"{row['traced_payload_bytes']:>8} {row['wire_class']:<6} "
                f"{resume:<16}")
        tele = e.get("telemetry", {}).get("ok")
        tele_s = "-" if tele is None else ("ok" if tele else "MISMATCH")
        lines.append(
            f"{'':<9} {'psums: ' + str(e['psum_sites']):<22} "
            f"max {e['psum_max_bytes']:>3} B   telemetry {tele_s}   "
            f"w-free {'yes' if e['w_independent'] else 'NO'}   "
            f"violations {len(e['violations'])}")
    lines.append("-" * len(hdr))
    lines.append(f"total violations: {report['violations_total']} — "
                 f"{'PASS' if report['ok'] else 'FAIL'}")
    return "\n".join(lines)
