"""Three-term roofline model from a compiled dry-run artifact.

    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective = coll_bytes  / (chips * 50e9   B/s per ICI link * links)

Hardware constants: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI). cost_analysis FLOPs/bytes are whole-program totals over all devices
unless XLA reports per-partition — empirically on the CPU backend with
SPMD partitioning, `flops` / `bytes accessed` are per-program-instance
(the partitioned module), so terms divide by 1 and chips enter through
the explicit `chips` arg where needed; we record both raw and per-chip.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.analysis.hlo import collective_bytes, count_ops

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link
ICI_LINKS = 4              # usable links per chip on a 2D torus slice


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per chip
    hlo_bytes: float            # per chip
    coll_bytes: float           # per chip
    coll_breakdown: Dict[str, int]
    coll_ops: Dict[str, int]
    model_flops: float          # 6*N*D (analytic, whole step, all chips)
    bytes_per_device: float     # from memory_analysis
    output_bytes: float = 0.0
    temp_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Roofline-implied MFU: useful FLOPs / (chips * peak * step_time)."""
        denom = self.chips * PEAK_FLOPS * self.step_time
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d |= dict(t_compute=self.t_compute, t_memory=self.t_memory,
                  t_collective=self.t_collective, bottleneck=self.bottleneck,
                  step_time=self.step_time, mfu=self.mfu,
                  useful_flops_fraction=self.useful_flops_fraction)
        return d


def model_flops_for(cfg, shape, n_tokens: Optional[int] = None) -> float:
    """Analytic MODEL_FLOPS for the cell: 6*N_active*D tokens (train) or
    2*N_active*D (forward-only serve steps)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_roofline(arch: str, shape_name: str, mesh_name: str, chips: int,
                   cost: dict, mem: dict, hlo_text: str,
                   model_flops: float) -> Roofline:
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        coll_ops=count_ops(hlo_text),
        model_flops=model_flops,
        bytes_per_device=float(mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0)),
        output_bytes=float(mem.get("output_size_in_bytes", 0)),
        temp_bytes=float(mem.get("temp_size_in_bytes", 0)),
    )
