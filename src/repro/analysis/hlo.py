"""HLO text parsing: collective byte extraction for the roofline model.

cost_analysis() reports FLOPs and memory traffic but not collective
volume, so we parse the optimized HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their payload
sizes. Shapes are parsed from the op's result type string.

Async pairs: `op-start` returns a tuple `(operands..., results...)` and
`op-done` returns the result again, so a naive sum over every shape in
every matched line double counts twice over — once by summing the operand
halves of the start tuples, once by counting the done ops. Here the
`-start`/`-done` suffix is parsed structurally (no substring matching on
the argument list), `-done` lines are skipped, and `-start` tuples only
charge their result half.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  or bf16[4096]  or (f32[2], s32[3]) tuples
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")

# "  %name = TYPE op-name(...)" — capture result type text + op + async
# suffix (captured, so "-done" is detected on the op itself rather than by
# substring-matching the whole line, which misfires on operand names)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", )


def _shape_bytes_list(type_text: str) -> List[int]:
    sizes = []
    for dt, dims in _SHAPE_RE.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    return sizes


def _shape_bytes(type_text: str) -> int:
    return sum(_shape_bytes_list(type_text))


def _payload_bytes(type_text: str, suffix: str) -> int:
    sizes = _shape_bytes_list(type_text)
    if suffix == "-start" and len(sizes) >= 2:
        # async start result = (operands..., results...): the operand half
        # aliases the inputs, only the result half is collective payload
        sizes = sizes[len(sizes) // 2:]
    return sum(sizes)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of payload bytes per collective kind.

    `-done` ops are skipped and `-start` tuple results only count their
    result half, so async pairs are charged exactly once.
    """
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_text, kind, suffix = m.group(1), m.group(2), m.group(3) or ""
        if suffix == "-done":
            continue
        out[kind] += _payload_bytes(type_text, suffix)
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m and (m.group(3) or "") != "-done":
            counts[m.group(2)] += 1
    return dict(counts)
