"""HLO text parsing: collective byte extraction for the roofline model.

cost_analysis() reports FLOPs and memory traffic but not collective
volume, so we parse the optimized HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their operand
sizes. Shapes are parsed from the op's result/operand type strings.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  or bf16[4096]  or (f32[2], s32[3]) tuples
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")

# "  %name = TYPE op-name(...)" — capture result type text + op
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result-shape bytes per collective kind (proxy for payload).

    `-done` ops are skipped so async pairs are not double counted.
    """
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        type_text, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_text)
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m and "-done(" not in line:
            counts[m.group(2)] += 1
    return dict(counts)
