"""Vertex partitioners for the distributed engines.

The engines use owner = vertex // n_loc (uniform contiguous ranges), so
load-balancing is done by *relabeling*: vertices are permuted so that the
uniform ranges receive near-equal degree sums (snake/boustrophedon greedy
over degree-sorted vertices). On power-law graphs this flattens the
per-shard walk load (visits ∝ degree — Lemma 2), which is the straggler
story: the max-loaded shard sets the super-step time.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.graph import CSRGraph, from_edges


def degree_balanced_relabel(graph: CSRGraph, shards: int
                            ) -> Tuple[CSRGraph, np.ndarray]:
    """Returns (relabeled graph, perm) with perm[old_id] = new_id such that
    uniform contiguous ranges of the new ids have ~equal degree sums."""
    n = graph.n
    n_loc = math.ceil(n / shards)
    deg = np.asarray(graph.out_deg)
    order = np.argsort(-deg, kind="stable")  # heavy first
    # snake assignment: 0,1,..,P-1,P-1,..,1,0,0,1,... balances prefix sums
    shard_seq = []
    fwd = list(range(shards))
    i = 0
    while len(shard_seq) < n:
        shard_seq.extend(fwd if i % 2 == 0 else fwd[::-1])
        i += 1
    shard_of = np.empty(n, np.int64)
    slot_in_shard = np.zeros(shards, np.int64)
    new_id = np.empty(n, np.int64)
    for rank, v in enumerate(order):
        p = shard_seq[rank]
        if slot_in_shard[p] >= n_loc:  # shard full: next free shard
            p = int(np.argmin(slot_in_shard))
        new_id[v] = p * n_loc + slot_in_shard[p]
        slot_in_shard[p] += 1
        shard_of[v] = p
    # rebuild edges under the new labels
    src = new_id[np.asarray(graph.edge_src())]
    dst = new_id[np.asarray(graph.col_idx)]
    g2 = from_edges(src, dst, n_loc * shards, undirected=False, dedup=False)
    # note: n padded to n_loc*shards; padding vertices are isolated
    return g2, new_id


def shard_load_stats(graph: CSRGraph, shards: int) -> dict:
    """Per-shard degree-sum imbalance under uniform contiguous ranges."""
    n_loc = math.ceil(graph.n / shards)
    deg = np.asarray(graph.out_deg)
    deg = np.concatenate([deg, np.zeros(n_loc * shards - len(deg),
                                        deg.dtype)])
    per_shard = deg.reshape(shards, n_loc).sum(axis=1)
    return dict(per_shard=per_shard.tolist(),
                max=int(per_shard.max()),
                mean=float(per_shard.mean()),
                imbalance=float(per_shard.max() / max(per_shard.mean(), 1)))
