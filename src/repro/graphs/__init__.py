from repro.graphs.generators import (GENERATORS, barabasi_albert,
                                     barabasi_albert_hub, directed_web,
                                     doc_link_graph, erdos_renyi, grid2d,
                                     random_regular, ring)

__all__ = ["GENERATORS", "barabasi_albert", "barabasi_albert_hub",
           "directed_web", "doc_link_graph", "erdos_renyi", "grid2d",
           "random_regular", "ring"]
