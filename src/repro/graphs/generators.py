"""Synthetic graph generators (host-side numpy; emit CSRGraph).

Families chosen to exercise the paper's claims: low-diameter expanders,
high-diameter rings/grids (where sub-diameter running time matters),
power-law webs (congestion stress), and directed graphs for Section 5.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import CSRGraph, from_edges


def ring(n: int) -> CSRGraph:
    v = np.arange(n)
    return from_edges(v, (v + 1) % n, n, undirected=True)


def grid2d(rows: int, cols: int) -> CSRGraph:
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    src = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    dst = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    return from_edges(src, dst, n, undirected=True)


def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> CSRGraph:
    """G(n, p) with p = avg_deg/n, plus a ring to guarantee connectivity."""
    rng = np.random.default_rng(seed)
    p = min(1.0, avg_deg / max(n - 1, 1))
    m_target = int(p * n * (n - 1) / 2)
    src = rng.integers(0, n, size=2 * m_target + n)
    dst = rng.integers(0, n, size=2 * m_target + n)
    keep = src != dst
    src, dst = src[keep][:m_target], dst[keep][:m_target]
    ring_v = np.arange(n)
    src = np.concatenate([src, ring_v])
    dst = np.concatenate([dst, (ring_v + 1) % n])
    return from_edges(src, dst, n, undirected=True)


def barabasi_albert(n: int, m_attach: int = 3, seed: int = 0) -> CSRGraph:
    """Preferential attachment (power-law degrees) — congestion stressor."""
    rng = np.random.default_rng(seed)
    m_attach = max(int(m_attach), 1)
    m0 = max(m_attach, 2)
    src_l, dst_l = [], []
    # seed clique
    for i in range(m0):
        for j in range(i + 1, m0):
            src_l.append(i)
            dst_l.append(j)
    targets = list(range(m0)) * 2
    for v in range(m0, n):
        chosen = set()
        while len(chosen) < m_attach:
            chosen.add(int(targets[rng.integers(0, len(targets))]))
        for u in chosen:
            src_l.append(v)
            dst_l.append(u)
            targets.extend([v, u])
    return from_edges(np.array(src_l), np.array(dst_l), n, undirected=True)


def barabasi_albert_hub(n: int, m_attach: int = 3, seed: int = 0) -> CSRGraph:
    """Preferential attachment plus a forced hub wired to every 4th vertex:
    max degree ~ n/4 while the median degree stays ~ m_attach. The
    max_deg >> typical_deg regime is what the degree-bucketed sampler
    exists for (the flat chain pays O(max_deg) at EVERY vertex here), so
    this is the stress fixture for its tests and benchmarks."""
    base = barabasi_albert(n, m_attach, seed)
    src = np.repeat(np.arange(base.n), np.asarray(base.out_deg))
    dst = np.asarray(base.col_idx)
    hub = 0
    spokes = np.arange(0, n, 4)
    spokes = spokes[spokes != hub]
    src = np.concatenate([src, np.full(len(spokes), hub)])
    dst = np.concatenate([dst, spokes])
    return from_edges(src, dst, n, undirected=True)


def random_regular(n: int, d: int, seed: int = 0) -> CSRGraph:
    """Union of d/2 random perfect matchings-ish permutations (expander whp)."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    for _ in range(max(d // 2, 1)):
        perm = rng.permutation(n)
        src_l.append(np.arange(n))
        dst_l.append(perm)
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    keep = src != dst
    return from_edges(src[keep], dst[keep], n, undirected=True)


def directed_web(n: int, avg_out_deg: float = 6.0, seed: int = 0, *,
                 alpha: float = 1.8) -> CSRGraph:
    """Directed web-like graph: power-law *in*-degree attractiveness, every
    vertex has out-degree >= 1 (no dangling). Exercises Section 5.

    Signature matches the launch driver's positional (n, avg_deg, seed)
    generator convention; the power-law exponent is keyword-only."""
    rng = np.random.default_rng(seed)
    # attractiveness ∝ (rank+1)^{-alpha}
    attract = (np.arange(n) + 1.0) ** (-alpha)
    attract /= attract.sum()
    out_deg = np.maximum(1, rng.poisson(avg_out_deg, size=n))
    src = np.repeat(np.arange(n), out_deg)
    dst = rng.choice(n, size=src.shape[0], p=attract)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # guarantee out_deg >= 1 after self-loop removal
    missing = np.setdiff1d(np.arange(n), np.unique(src))
    if len(missing):
        src = np.concatenate([src, missing])
        dst = np.concatenate([dst, (missing + 1) % n])
    return from_edges(src, dst, n, undirected=False)


def doc_link_graph(n_docs: int, seed: int = 0) -> CSRGraph:
    """Synthetic document citation/hyperlink graph for the data-weighting
    integration example (directed, power-law authority)."""
    return directed_web(n_docs, avg_out_deg=8.0, seed=seed, alpha=1.5)


GENERATORS = {
    "ring": ring,
    "grid2d": grid2d,
    "erdos_renyi": erdos_renyi,
    "barabasi_albert": barabasi_albert,
    "barabasi_albert_hub": barabasi_albert_hub,
    "random_regular": random_regular,
    "directed_web": directed_web,
}
