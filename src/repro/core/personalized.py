"""Personalized PageRank via the same Monte-Carlo machinery.

PPR(s) is the stationary distribution of the walk that resets to the
*source distribution* s instead of uniform. In the terminate-at-reset
Monte-Carlo formulation (Avrachenkov et al.; Bahmani et al.), that is
exactly Algorithm 1 with all walks started from s:

    ppr_v = zeta_v * eps / W        (W walks started ~ s)

The walk-array engine already accepts explicit sources, so this is a thin,
fully-supported extension of the paper's framework (used e.g. for
seed-based relevance and local community scoring).

The batched multi-query realization (one shard_map superstep advancing
thousands of queries over the Lemma-1 count wire) lives in
`core/personalized_batch.py`; both derive their walk-to-source assignment
from `source_start_counts` so the single-query and batched engines draw
from the same start distribution for the same key.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine_walks
from repro.core.graph import CSRGraph

# Round cap for the terminate-at-reset walk loop. Walks terminate w.p. eps
# per round, so P(any round beyond r) <= W * (1-eps)^r — at eps >= 0.1 the
# loop exits long before this cap; it exists only to bound a malformed
# (eps ~ 0) call.
DEFAULT_MAX_ROUNDS = 100_000

_START_FOLD = 0x5052_5354  # "PRST": start-assignment substream tag


def _host_key_words(key: jnp.ndarray) -> np.ndarray:
    """uint32 words of `key` on the host (typed or legacy raw keys)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, dtype=np.uint32).reshape(-1)


def source_start_counts(key: jnp.ndarray, weights: np.ndarray,
                        walks_total: int) -> np.ndarray:
    """Multinomial(walks_total, weights) walk-to-source assignment.

    Derived from `key` via fold_in onto a dedicated substream, so (a) two
    keys give two independent start assignments (the estimator's variance
    story needs the starts to resample), (b) the same key is bit-exactly
    reproducible, and (c) the draw never collides with the walk-step
    uniforms consumed downstream from the unfolded key.
    """
    weights = np.asarray(weights, dtype=np.float64)
    weights = weights / weights.sum()
    words = _host_key_words(jax.random.fold_in(key, _START_FOLD))
    rng = np.random.default_rng(words)
    return rng.multinomial(int(walks_total), weights)


def normalize_query(sources, weights, n: int):
    """Validate and canonicalize a (sources, weights) PPR query."""
    sources = np.asarray(sources, dtype=np.int32).reshape(-1)
    if sources.size == 0:
        raise ValueError("PPR query needs at least one source vertex")
    if sources.min() < 0 or sources.max() >= n:
        raise ValueError(f"source vertex out of range [0, {n})")
    if weights is None:
        weights = np.full(len(sources), 1.0 / len(sources))
    weights = np.asarray(weights, dtype=np.float64)
    if weights.shape != sources.shape:
        raise ValueError("weights must match sources")
    return sources, weights / weights.sum()


def personalized_pagerank(graph: CSRGraph, eps: float, sources,
                          walks_total: int, key: Optional[jnp.ndarray] = None,
                          weights=None,
                          max_rounds: int = DEFAULT_MAX_ROUNDS) -> jnp.ndarray:
    """Monte-Carlo PPR for a seed set.

    sources: int vertex ids [k]; weights: optional distribution over them.
    `key` drives BOTH the walk-to-source multinomial (via
    `source_start_counts`) and the walk steps — same key, bit-identical
    result; different keys, independent estimates. Returns the
    (unnormalized-estimator) PPR vector [n].
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    sources, weights = normalize_query(sources, weights, graph.n)
    counts = source_start_counts(key, weights, walks_total)
    starts = jnp.asarray(np.repeat(sources, counts), dtype=jnp.int32)

    state = engine_walks.init_state(graph, 0, key, sources=starts)
    state = engine_walks._run_while(graph.row_ptr, graph.col_idx,
                                    graph.out_deg, state, float(eps),
                                    int(max_rounds), False)
    return state.zeta.astype(jnp.float32) * (eps / walks_total)


def exact_ppr(graph: CSRGraph, eps: float, sources, weights=None) -> np.ndarray:
    """Dense linear-solve oracle: ppr = eps * s (I - (1-eps) Q)^-1."""
    from repro.core.graph import transition_matrix

    n = graph.n
    sources = np.asarray(sources)
    s = np.zeros(n)
    if weights is None:
        s[sources] = 1.0 / len(sources)
    else:
        w = np.asarray(weights, dtype=np.float64)
        s[sources] = w / w.sum()
    Q = (transition_matrix(graph, 0.0) - 0.0)  # pure walk matrix
    A = np.eye(n) - (1 - eps) * Q
    return eps * np.linalg.solve(A.T, s)
