"""Personalized PageRank via the same Monte-Carlo machinery.

PPR(s) is the stationary distribution of the walk that resets to the
*source distribution* s instead of uniform. In the terminate-at-reset
Monte-Carlo formulation (Avrachenkov et al.; Bahmani et al.), that is
exactly Algorithm 1 with all walks started from s:

    ppr_v = zeta_v * eps / W        (W walks started ~ s)

The walk-array engine already accepts explicit sources, so this is a thin,
fully-supported extension of the paper's framework (used e.g. for
seed-based relevance and local community scoring).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine_walks
from repro.core.graph import CSRGraph


def personalized_pagerank(graph: CSRGraph, eps: float, sources,
                          walks_total: int, key: Optional[jnp.ndarray] = None,
                          weights=None) -> jnp.ndarray:
    """Monte-Carlo PPR for a seed set.

    sources: int vertex ids [k]; weights: optional distribution over them.
    Returns the (unnormalized-estimator) PPR vector [n].
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    sources = np.asarray(sources, dtype=np.int32)
    if weights is None:
        weights = np.full(len(sources), 1.0 / len(sources))
    weights = np.asarray(weights, dtype=np.float64)
    weights = weights / weights.sum()
    counts = np.random.default_rng(0).multinomial(walks_total, weights)
    starts = jnp.asarray(np.repeat(sources, counts), dtype=jnp.int32)

    state = engine_walks.init_state(graph, 0, key, sources=starts)
    state = engine_walks._run_while(graph.row_ptr, graph.col_idx,
                                    graph.out_deg, state, float(eps),
                                    100_000, False)
    return state.zeta.astype(jnp.float32) * (eps / walks_total)


def exact_ppr(graph: CSRGraph, eps: float, sources, weights=None) -> np.ndarray:
    """Dense linear-solve oracle: ppr = eps * s (I - (1-eps) Q)^-1."""
    from repro.core.graph import transition_matrix

    n = graph.n
    sources = np.asarray(sources)
    s = np.zeros(n)
    if weights is None:
        s[sources] = 1.0 / len(sources)
    else:
        w = np.asarray(weights, dtype=np.float64)
        s[sources] = w / w.sum()
    Q = (transition_matrix(graph, 0.0) - 0.0)  # pure walk matrix
    A = np.eye(n) - (1 - eps) * Q
    return eps * np.linalg.solve(A.T, s)
