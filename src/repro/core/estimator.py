"""PageRank estimation from visit counters + error metrics."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def pagerank_from_visits(zeta, n: int, walks_per_node: int,
                         eps: float) -> np.ndarray:
    """pi_tilde_v = zeta_v * eps / (n * K)   (Algorithm 1, step 12).

    Scales on the host in float64: the integer visit counters exceed
    float32's 2**24 integer-exact range once n * walks_per_node / eps gets
    large, so a float32 cast would corrupt zeta *before* the scale. JAX
    x64 is globally off in this repo, hence numpy rather than jnp here."""
    zeta64 = np.asarray(zeta).astype(np.float64)
    return zeta64 * (eps / (float(n) * float(walks_per_node)))


def normalized(pi: jnp.ndarray) -> jnp.ndarray:
    return pi / jnp.sum(pi)


def l1_error(est, ref) -> float:
    return float(np.abs(np.asarray(est, dtype=np.float64) - np.asarray(ref, dtype=np.float64)).sum())


def linf_error(est, ref) -> float:
    return float(np.abs(np.asarray(est, dtype=np.float64) - np.asarray(ref, dtype=np.float64)).max())


def max_rel_error(est, ref) -> float:
    est = np.asarray(est, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    return float((np.abs(est - ref) / np.maximum(ref, 1e-30)).max())


def topk_overlap(est, ref, k: int = 10) -> float:
    """|top-k(est) ∩ top-k(ref)| / k — ranking quality (PageRank's use-case)."""
    a = set(np.argsort(-np.asarray(est))[:k].tolist())
    b = set(np.argsort(-np.asarray(ref))[:k].tolist())
    return len(a & b) / k
