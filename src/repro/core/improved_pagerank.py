"""IMPROVED-PAGERANK-ALGORITHM (Algorithm 2) + the Section-5 directed/LOCAL
variant.

Three phases, exactly as in the paper:

  Phase 1 — every node v pre-computes short PageRank walks of length
    lambda = ceil(sqrt(log n)): d(v)*eta of them in the undirected/CONGEST
    setting (Lemma 2: visits ∝ degree), or a uniform per-node pool in the
    directed/LOCAL setting (Section 5). Trajectories and the edge ids taken
    are recorded; a short walk may terminate early if its eps-reset fires.

  Phase 2 — each of the n*K long walks stitches unused coupons at connector
    nodes via direct communication (O(1) rounds per stitch). Coupons are
    consumed in natural order, which is distributionally identical to
    uniform-without-replacement because coupons are iid and consumption
    order is independent of their realizations. If a node's pool is
    exhausted (eta too small — the paper's whp bound violated), the walk
    falls back to naive walking (tracked in `exhausted_walks`).

  Phase 3 — visits of *used* coupons are counted by re-tracing trajectories
    (the recorded edge ids make the reverse-trace message accounting exact);
    unfinished walks complete naively to their exact eps-reset so the
    estimator stays unbiased (the paper caps at l = log n/eps whp — we walk
    the true tail instead, a strict-superset guarantee).

Estimator: pi_tilde_v = zeta_v * eps / (n*K), identical to Algorithm 1.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CongestReport, RoundTrace, default_bandwidth
from repro.core.engine_walks import WalkState, _step_traced
from repro.core.estimator import pagerank_from_visits
from repro.core.graph import CSRGraph
from repro.core.simple_pagerank import PageRankResult, walks_per_node_for


@dataclasses.dataclass
class ImprovedResult(PageRankResult):
    lam: int = 0
    eta: int = 0
    stitch_iterations: int = 0
    phase1_rounds: int = 0
    phase2_rounds: int = 0
    phase3_rounds: int = 0
    tail_rounds: int = 0
    exhausted_walks: int = 0
    coupons_created: int = 0
    coupons_used: int = 0


# ---------------------------------------------------------------------------
# coupon pool sizing (shared with the distributed engine)
# ---------------------------------------------------------------------------

def coupon_pool_sizes(graph: CSRGraph, eps: float, walks_per_node: int,
                      lam: int, *, eta: Optional[int] = None,
                      eta_safety: float = 2.0,
                      degree_proportional: bool = True,
                      ell: Optional[int] = None) -> Tuple[int, np.ndarray]:
    """Phase-1 coupon pool sizes, shared by every Algorithm-2-family engine.

    Degree-proportional (undirected/CONGEST, Lemma 2): d(v)*eta coupons per
    node. eta is sized from the expected stitches-per-node: a long walk has
    expected length 1/eps => ~1/(eps*lam)+1 stitches; connectors land
    ∝ d(v)/Σd (undirected near-stationarity). The paper's Theta(log^3 n/eps)
    overprovisions for whp bounds; we size for the expectation ×safety and
    keep the naive-walk fallback for the (counted) exhaustion tail.
    Isolated vertices get one coupon so every request resolves
    deterministically.

    Uniform (directed/LOCAL, Section 5: `degree_proportional=False`): no
    degree bound relates visits to d(v) on a directed graph, so every node
    gets the same eta*ceil(log n) coupons, with eta = ceil(eta_safety *
    K*ell/lam) — K*ell/lam is the per-node stitch demand if the whole
    walk load concentrated ∝ 1/n, and the extra ceil(log n) factor covers
    connector skew (the paper sends polynomially many coupons; LOCAL
    bandwidth is free, our buffers are not). Requires `ell` (the whp walk
    length cap) unless `eta` is given explicitly.

    Returns (eta, pool_size[n]).
    """
    deg_np = np.asarray(graph.out_deg)
    n = graph.n
    if degree_proportional:
        if eta is None:
            exp_stitches = n * walks_per_node * (1.0 / (eps * lam) + 1.0)
            eta = max(1, int(math.ceil(
                eta_safety * exp_stitches / max(deg_np.sum(), 1))))
        return int(eta), np.maximum(deg_np.astype(np.int64) * eta, 1)
    if eta is None:
        if ell is None:
            raise ValueError("uniform pool sizing needs ell (or explicit eta)")
        eta = max(1, int(math.ceil(eta_safety * walks_per_node * ell / lam)))
    log_n = math.log(max(n, 2))
    per_node = int(eta) * max(1, int(math.ceil(log_n)))
    return int(eta), np.full(n, per_node, dtype=np.int64)


# ---------------------------------------------------------------------------
# Phase 1: short walks with trajectory + edge-id recording
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("eps", "lam"))
def _phase1_scan(row_ptr, col_idx, out_deg, src, key, eps: float, lam: int):
    S = src.shape[0]

    def step(carry, k):
        pos, alive = carry
        k_term, k_edge = jax.random.split(k)
        u_term = jax.random.uniform(k_term, (S,))
        deg = out_deg[pos]
        survive = alive & (u_term >= eps) & (deg > 0)
        u_edge = jax.random.uniform(k_edge, (S,))
        j = jnp.minimum((u_edge * jnp.maximum(deg, 1)).astype(jnp.int32),
                        jnp.maximum(deg - 1, 0))
        edge_ids = row_ptr[pos] + j
        dst = col_idx[jnp.clip(edge_ids, 0, col_idx.shape[0] - 1)]
        new_pos = jnp.where(survive, dst, pos)
        rec = dict(pos=new_pos, moved=survive,
                   edge=jnp.where(survive, edge_ids, -1))
        return (new_pos, survive), rec

    keys = jax.random.split(key, lam)
    (final_pos, _), recs = jax.lax.scan(step, (src, jnp.ones((S,), bool)), keys)
    # recs["pos"]: [lam, S] arrival positions; moved: [lam, S]
    valid_arrivals = jnp.sum(recs["moved"], axis=0).astype(jnp.int32)
    terminated = ~recs["moved"][-1]  # reset fired at or before step lam
    return dict(
        traj=recs["pos"],            # [lam, S]
        edges=recs["edge"],          # [lam, S]  (-1 where no move)
        moved=recs["moved"],         # [lam, S]
        dest=final_pos,              # [S]
        valid_arrivals=valid_arrivals,
        terminated=terminated,
    )


def _edge_traces(edges: jnp.ndarray, moved: jnp.ndarray, m: int,
                 mask: Optional[jnp.ndarray] = None) -> List[RoundTrace]:
    """Per-step CONGEST accounting from recorded edge ids ([lam, S])."""
    traces = []
    lam = edges.shape[0]
    for i in range(lam):
        mv = moved[i] if mask is None else (moved[i] & mask)
        eids = jnp.where(mv, edges[i], m)  # dump masked into segment m
        counts = jax.ops.segment_sum(mv.astype(jnp.int32), eids,
                                     num_segments=m + 1)[:m]
        total = int(jnp.sum(counts))
        traces.append(RoundTrace(
            active_walks=int(jnp.sum(mv)),
            messages=int(jnp.sum(counts > 0)),
            max_edge_count=int(jnp.max(counts)) if m else 0,
            total_count=total,
        ))
    return traces


# ---------------------------------------------------------------------------
# Phase 2: stitching
# ---------------------------------------------------------------------------

@jax.jit
def _allocate_coupons(cur, active, next_coupon, pool_start, pool_size):
    """Give each active walk a distinct next-unused coupon of its connector.

    Returns (coupon_id [-1 if exhausted/inactive], new_next_coupon).
    Walks at the same connector receive consecutive offsets via a
    sort-and-rank within the connector group.
    """
    W = cur.shape[0]
    n = next_coupon.shape[0]
    vid = jnp.where(active, cur, n)  # inactive walks sort to the end
    order = jnp.argsort(vid)
    sorted_v = vid[order]
    # rank of each sorted element within its equal-value run
    idx = jnp.arange(W)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_v[1:] != sorted_v[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    rank = jnp.zeros((W,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    offset = next_coupon[jnp.clip(cur, 0, n - 1)] + rank
    ok = active & (offset < pool_size[jnp.clip(cur, 0, n - 1)])
    coupon_id = jnp.where(ok, pool_start[jnp.clip(cur, 0, n - 1)] + offset, -1)
    req = jax.ops.segment_sum(active.astype(jnp.int32), jnp.clip(cur, 0, n - 1),
                              num_segments=n)
    # pool pointer advances by the number of *requests* (paper deletes coupons
    # on sampling); clip to pool size
    new_next = jnp.minimum(next_coupon + req, pool_size)
    return coupon_id, ok, new_next


# ---------------------------------------------------------------------------
# main driver
# ---------------------------------------------------------------------------

def improved_pagerank(
    graph: CSRGraph,
    eps: float,
    *,
    walks_per_node: int | None = None,
    lam: int | None = None,
    eta: int | None = None,
    key: jnp.ndarray | None = None,
    degree_proportional: bool = True,
    local_model: bool = False,
    eta_safety: float = 2.0,
    bandwidth_bits: int | None = None,
) -> ImprovedResult:
    """Algorithm 2 (undirected/CONGEST) or Section 5 (directed/LOCAL when
    `degree_proportional=False, local_model=True`)."""
    n, m = graph.n, graph.m
    key = key if key is not None else jax.random.PRNGKey(0)
    K = walks_per_node or walks_per_node_for(n, eps)
    log_n = math.log(max(n, 2))
    if lam is None:
        lam = max(1, int(math.ceil(math.sqrt(log_n if not local_model
                                             else log_n / eps))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))

    eta, pool_size_np = coupon_pool_sizes(
        graph, eps, K, lam, eta=eta, eta_safety=eta_safety,
        degree_proportional=degree_proportional, ell=ell)

    pool_start_np = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(pool_size_np, out=pool_start_np[1:])
    S = int(pool_start_np[-1])
    src = np.repeat(np.arange(n, dtype=np.int32), pool_size_np)

    key, k1, k2, k3 = jax.random.split(key, 4)

    # ---------------- Phase 1 ----------------
    p1 = _phase1_scan(graph.row_ptr, graph.col_idx, graph.out_deg,
                      jnp.asarray(src), k1, float(eps), int(lam))
    traces_p1 = _edge_traces(p1["edges"], p1["moved"], m)
    # +1 round: destinations report their ID to sources (direct comm, step 7)
    traces_p1.append(RoundTrace(active_walks=S, messages=S, max_edge_count=1,
                                total_count=S))

    # ---------------- Phase 2 ----------------
    pool_start = jnp.asarray(pool_start_np[:-1], dtype=jnp.int32)
    pool_size = jnp.asarray(pool_size_np, dtype=jnp.int32)
    next_coupon = jnp.zeros((n,), jnp.int32)

    W = n * K
    cur = jnp.tile(jnp.arange(n, dtype=jnp.int32), K)
    len_done = jnp.zeros((W,), jnp.int32)
    long_term = jnp.zeros((W,), bool)
    exhausted = jnp.zeros((W,), bool)
    used = jnp.zeros((S,), bool)

    dest = p1["dest"]
    c_term = p1["terminated"]
    c_len = p1["valid_arrivals"]

    stitch_iters = 0
    max_iters = int(math.ceil(ell / lam)) + 3
    for _ in range(max_iters):
        active = (~long_term) & (~exhausted) & (len_done <= ell - lam)
        if not bool(jnp.any(active)):
            break
        coupon_id, ok, next_coupon = _allocate_coupons(
            cur, active, next_coupon, pool_start, pool_size)
        newly_exhausted = active & (~ok)
        cid = jnp.clip(coupon_id, 0, S - 1)
        used = used.at[cid].max(ok)  # bool-or scatter; False writes are no-ops
        cur = jnp.where(ok, dest[cid], cur)
        len_done = jnp.where(ok, len_done + c_len[cid], len_done)
        long_term = long_term | (ok & c_term[cid])
        exhausted = exhausted | newly_exhausted
        stitch_iters += 1
    traces_p2 = [RoundTrace(active_walks=W, messages=W, max_edge_count=1,
                            total_count=W)] * stitch_iters

    # ---------------- tail: finish un-terminated walks naively ----------
    tail_active = (~long_term)
    tail_rounds = 0
    traces_tail: List[RoundTrace] = []
    zeta_tail = jnp.zeros((n,), jnp.int32)
    if bool(jnp.any(tail_active)):
        state = WalkState(pos=cur, alive=tail_active, zeta=zeta_tail,
                          key=k2, round=jnp.int32(0))
        while bool(jnp.any(state.alive)):
            state, stats = _step_traced(graph.row_ptr, graph.col_idx,
                                        graph.out_deg, state, float(eps),
                                        m, False)
            traces_tail.append(RoundTrace(
                active_walks=int(stats["active"]),
                messages=int(stats["messages"]),
                max_edge_count=int(stats["max_edge_count"]),
                total_count=int(stats["moved"])))
        zeta_tail = state.zeta
        tail_rounds = int(state.round)

    # ---------------- Phase 3: count visits of used coupons -------------
    # start visits of the W long walks:
    zeta = jnp.full((n,), K, dtype=jnp.int32) + zeta_tail
    # arrivals of used coupons: traj[i, s] counted when moved[i, s] & used[s]
    used_m = p1["moved"] & used[None, :]
    flat_pos = jnp.where(used_m, p1["traj"], n).reshape(-1)
    zeta = zeta + jax.ops.segment_sum(
        used_m.astype(jnp.int32).reshape(-1), flat_pos, num_segments=n + 1)[:n]
    traces_p3 = _edge_traces(p1["edges"], p1["moved"], m, mask=used)

    traces = traces_p1 + traces_p2 + traces_tail + traces_p3
    report = CongestReport(traces=traces, n=n,
                           bandwidth_bits=bandwidth_bits or default_bandwidth(n))
    pi = pagerank_from_visits(zeta, n, K, eps)
    return ImprovedResult(
        pi=pi, zeta=zeta, walks_per_node=K, eps=eps,
        logical_rounds=len(traces), report=report,
        lam=int(lam), eta=int(eta), stitch_iterations=stitch_iters,
        phase1_rounds=len(traces_p1), phase2_rounds=stitch_iters,
        phase3_rounds=len(traces_p3), tail_rounds=tail_rounds,
        exhausted_walks=int(jnp.sum(exhausted)),
        coupons_created=S, coupons_used=int(jnp.sum(used)),
    )


def directed_local_pagerank(graph: CSRGraph, eps: float, **kw) -> ImprovedResult:
    """Section 5: directed graphs in the LOCAL model — uniform per-node
    coupon pools (no degree bound available) and lambda = sqrt(log n / eps)."""
    kw.setdefault("degree_proportional", False)
    kw.setdefault("local_model", True)
    return improved_pagerank(graph, eps, **kw)
