"""Degree-bucketed aggregate multinomial sampler — the shared compute core
of every count-moving engine.

Problem: the conditional-binomial chain that splits an aggregate coupon
count over a vertex's out-edges is a scan whose width used to be the
GLOBAL max degree, so on power-law graphs one hub made every low-degree
vertex pay hub cost: per-round sampler FLOPs were n * max_deg.

Fix: group rows by power-of-two degree buckets. Bucket b holds rows with
degree in (2^(b-1), 2^b] (bucket 0: degree 0 and 1) and scans width
min(2^b, max_deg) <= 2 * degree, so the per-round FLOPs drop to
sum_v O(deg(v)) — per-node work proportional to local degree, the
property the paper's CONGEST model assumes. The grouping is a STATIC
permutation computed on the host at shard/build time and memoized (like
the engines' step makers); the per-round work is a python loop over the
O(log max_deg) buckets, each a single `kernels.multinomial_rows` call
(Pallas kernel or its jnp ref — same counter-RNG math, so `use_pallas`
never changes the draws).

Sharded engines run ONE traced program on every shard, so bucket
capacities must be shard-uniform: `build_layout_sharded` takes the max
row count per bucket over shards and pads each shard's permutation with
-1 sentinels (gathered as count 0 — they never sample, never ship).

`bucketed=False` (the pre-PR shape, kept for benchmarking and as the
degenerate fallback) is the SAME machinery with a single bucket of width
max_deg — one code path, two layouts.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.multinomial_rows import multinomial_rows
from repro.kernels.multinomial_rows.ref import multinomial_rows_ref


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static (hashable) shape of a bucketed row grouping.

    widths[b]: chain scan width of bucket b (min(2^b, max_deg)).
    caps[b]:   row slots in bucket b (shard-uniform max; >= real rows).
    n_rows:    number of real rows the permutation indexes into.
    """

    widths: Tuple[int, ...]
    caps: Tuple[int, ...]
    n_rows: int

    @property
    def total_rows(self) -> int:
        return sum(self.caps)

    @property
    def total_edges(self) -> int:
        """Flat bucketed-adjacency length: sum of caps[b] * widths[b]."""
        return sum(c * w for c, w in zip(self.caps, self.widths))

    @property
    def row_starts(self) -> Tuple[int, ...]:
        out, s = [], 0
        for c in self.caps:
            out.append(s)
            s += c
        return tuple(out)

    def tile(self, copies: int) -> "BucketLayout":
        """Layout for `copies` stacked replicas of the same row set (the
        Phase-1 home-major (home, vertex) row matrix)."""
        return BucketLayout(widths=self.widths,
                            caps=tuple(c * copies for c in self.caps),
                            n_rows=self.n_rows * copies)


def bucket_of(deg: np.ndarray) -> np.ndarray:
    """Power-of-two bucket index per degree: 0 for deg <= 1, else
    ceil(log2(deg))."""
    d = np.maximum(np.asarray(deg, np.int64), 1)
    return np.ceil(np.log2(d)).astype(np.int64)


@lru_cache(maxsize=256)
def _layout_cached(deg_bytes: bytes, rows_per_shard: int, shards: int,
                   max_deg: int, bucketed: bool):
    deg = np.frombuffer(deg_bytes, dtype=np.int32).reshape(shards,
                                                           rows_per_shard)
    if not bucketed or max_deg <= 1:
        perm = np.tile(np.arange(rows_per_shard, dtype=np.int32),
                       (shards, 1))
        layout = BucketLayout(widths=(max(max_deg, 1),),
                              caps=(rows_per_shard,),
                              n_rows=rows_per_shard)
        return layout, perm
    n_b = int(np.ceil(np.log2(max_deg))) + 1
    widths = tuple(min(1 << b, max_deg) for b in range(n_b))
    b_of = bucket_of(deg)
    counts = np.zeros((shards, n_b), np.int64)
    for p in range(shards):
        np.add.at(counts[p], b_of[p], 1)
    caps = tuple(int(c) for c in counts.max(axis=0))
    starts = np.concatenate([[0], np.cumsum(caps)[:-1]])
    perm = np.full((shards, int(sum(caps))), -1, np.int32)
    for p in range(shards):
        fill = starts.copy()
        for r in range(rows_per_shard):
            b = b_of[p, r]
            perm[p, fill[b]] = r
            fill[b] += 1
    layout = BucketLayout(widths=widths, caps=caps, n_rows=rows_per_shard)
    return layout, perm


def build_layout(deg: np.ndarray, max_deg: int, *,
                 bucketed: bool = True) -> Tuple[BucketLayout, np.ndarray]:
    """Single-shard layout: (layout, perm [total_rows] int32, -1 = pad)."""
    deg = np.ascontiguousarray(np.asarray(deg, np.int32))
    layout, perm = _layout_cached(deg.tobytes(), len(deg), 1, int(max_deg),
                                  bool(bucketed))
    return layout, perm[0]


def build_layout_sharded(deg: np.ndarray, max_deg: int, *,
                         bucketed: bool = True
                         ) -> Tuple[BucketLayout, np.ndarray]:
    """Shard-uniform layout from a [shards, n_loc] degree matrix:
    (layout with caps = max over shards, perm [shards, total_rows])."""
    deg = np.ascontiguousarray(np.asarray(deg, np.int32))
    shards, n_loc = deg.shape
    return _layout_cached(deg.tobytes(), n_loc, shards, int(max_deg),
                          bool(bucketed))


def bucketize_adjacency(nbr: np.ndarray, perm: np.ndarray,
                        layout: BucketLayout, *,
                        pad_dst: int = 0) -> np.ndarray:
    """Flat bucketed neighbor table [*, total_edges]: bucket b contributes
    a [caps[b], widths[b]] block of `nbr[perm]` rows (row-major). Padding
    slots point at `pad_dst` — they only ever carry zero counts.

    Round-trips to the flat padded adjacency bit-exactly: row perm[i]'s
    first widths[b] slots are nbr[perm[i], :widths[b]], and every slot
    beyond a row's bucket width is structurally count-free because the
    row's degree is <= its bucket width (tests/test_property.py).
    """
    nbr = np.asarray(nbr)
    lead = nbr.shape[:-2]
    flat = np.empty(lead + (layout.total_edges,), nbr.dtype)
    s_rows, s_edges = 0, 0
    for cap, w in zip(layout.caps, layout.widths):
        rows = perm[..., s_rows:s_rows + cap]
        blk = np.take_along_axis(
            nbr[..., :w], np.maximum(rows, 0)[..., None], axis=-2)
        blk = np.where((rows < 0)[..., None], pad_dst, blk)
        flat[..., s_edges:s_edges + cap * w] = blk.reshape(lead + (cap * w,))
        s_rows += cap
        s_edges += cap * w
    return flat


def sample_buckets(counts, deg, rid, key_words, perm, layout: BucketLayout,
                   *, eps: float, use_pallas: bool
                   ) -> Tuple[List[Tuple[jnp.ndarray, jnp.ndarray]],
                              jnp.ndarray, jnp.ndarray]:
    """Run the fused sampler over every bucket of `layout`.

    counts/deg/rid: [n_rows] int32 vectors in ORIGINAL row order;
    perm: [total_rows] int32 bucket-grouped row indices (-1 = padding).

    Returns (samples, occupancy, residual):
      samples   — per bucket (rows_b [caps[b]], T_b [caps[b], widths[b]+1])
                  with T_b column 0 the termination count;
      occupancy — [n_buckets] int32, rows with a nonzero count per bucket;
      residual  — scalar int32, sum over rows of (count - T.sum()): 0 by
                  construction (endpoint-exact chain), kept as a tripwire.
    """
    fn = multinomial_rows if use_pallas else multinomial_rows_ref
    n = counts.shape[0]
    samples, occ, residual = [], [], jnp.int32(0)
    for start, cap, w in zip(layout.row_starts, layout.caps, layout.widths):
        rows_b = jnp.asarray(perm[start:start + cap])
        ok = rows_b >= 0
        safe = jnp.clip(rows_b, 0, n - 1)
        c_b = jnp.where(ok, counts[safe], 0)
        d_b = jnp.where(ok, deg[safe], 0)
        r_b = jnp.where(ok, rid[safe], 0)
        T_b = fn(c_b, d_b, r_b, key_words, eps=eps, width=w)
        samples.append((rows_b, T_b))
        occ.append(jnp.sum(c_b > 0))
        residual = residual + jnp.sum(c_b) - jnp.sum(T_b)
    return samples, jnp.stack(occ).astype(jnp.int32), residual


def flatten_moves(samples) -> jnp.ndarray:
    """Per-edge counts [total_edges] aligned with `bucketize_adjacency`
    (termination column dropped)."""
    return jnp.concatenate([T[:, 1:].reshape(-1) for _, T in samples])


def scatter_cells(samples, layout: BucketLayout, max_deg: int
                  ) -> jnp.ndarray:
    """Dense per-row outcome cells [n_rows * (max_deg + 1)] int32: cell
    r*(max_deg+1) is row r's termination count, cell r*(max_deg+1)+1+j its
    out-edge-j count (0 beyond the row's bucket width — structurally
    count-free). This is the Phase-1 reply layout of the 3-phase engines.
    """
    size = layout.n_rows * (max_deg + 1)
    out = jnp.zeros((size + 1,), jnp.int32)
    for (rows_b, T_b), w in zip(samples, layout.widths):
        base = jnp.where(rows_b < 0, size, rows_b * (max_deg + 1))
        offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                1 + jnp.arange(w, dtype=jnp.int32)])
        idx = jnp.minimum(base[:, None] + offs[None, :], size)
        out = out.at[idx].set(T_b, mode="drop")
    return out[:size]
