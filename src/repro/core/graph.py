"""Graph representation for the distributed PageRank engines.

CSR over int32 indices. Device arrays so every engine (count-based,
walk-array, distributed shard_map) consumes the same structure.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row adjacency (out-edges).

    Attributes:
      row_ptr: [n+1] int32, row_ptr[v]..row_ptr[v+1] slice of col_idx.
      col_idx: [m] int32 destination vertex of each out-edge.
      out_deg: [n] int32 out-degree (== diff of row_ptr, kept for fast gather).
      n, m:    static sizes.
      undirected: True if the edge set is symmetric.
    """

    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    out_deg: jnp.ndarray
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    undirected: bool = dataclasses.field(metadata=dict(static=True))

    @property
    def max_out_deg(self) -> int:
        return int(np.asarray(self.out_deg).max()) if self.n else 0

    def edge_src(self) -> jnp.ndarray:
        """[m] int32 source vertex of each edge (expanded from row_ptr)."""
        return jnp.asarray(
            np.repeat(np.arange(self.n, dtype=np.int32), np.asarray(self.out_deg)),
            dtype=jnp.int32,
        )


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    *,
    undirected: bool = False,
    dedup: bool = True,
) -> CSRGraph:
    """Build a CSRGraph from (src, dst) edge arrays.

    If `undirected`, each edge is inserted in both directions.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if dedup and len(src):
        keys = src * n + dst
        keys = np.unique(keys)
        src, dst = keys // n, keys % n
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    m = len(src)
    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(out_deg, out=row_ptr[1:])
    return CSRGraph(
        row_ptr=jnp.asarray(row_ptr),
        col_idx=jnp.asarray(dst.astype(np.int32)),
        out_deg=jnp.asarray(out_deg),
        n=int(n),
        m=int(m),
        undirected=bool(undirected),
    )


def padded_adjacency(graph: CSRGraph, max_deg: int | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense padded neighbor table for the count engine.

    Returns (nbr [n, max_deg] int32, valid [n, max_deg] bool). Padded slots
    point at the vertex itself (never selected because valid=False there).
    """
    n = graph.n
    md = max_deg or graph.max_out_deg
    row_ptr = np.asarray(graph.row_ptr)
    col = np.asarray(graph.col_idx)
    deg = np.asarray(graph.out_deg)
    nbr = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, max(md, 1)))
    valid = np.zeros((n, max(md, 1)), dtype=bool)
    for v in range(n):
        d = deg[v]
        if d:
            nbr[v, :d] = col[row_ptr[v] : row_ptr[v] + d]
            valid[v, :d] = True
    return jnp.asarray(nbr), jnp.asarray(valid)


def transition_matrix(graph: CSRGraph, eps: float) -> np.ndarray:
    """Dense PageRank transition matrix P = (eps/n)J + (1-eps)Q (row-stochastic).

    Dangling rows of Q get uniform 1/n (Avrachenkov convention — matches the
    engines, which treat a dangling vertex as an immediate reset).
    Only for small test graphs.
    """
    n = graph.n
    row_ptr = np.asarray(graph.row_ptr)
    col = np.asarray(graph.col_idx)
    deg = np.asarray(graph.out_deg)
    Q = np.zeros((n, n), dtype=np.float64)
    for v in range(n):
        d = deg[v]
        if d:
            Q[v, col[row_ptr[v] : row_ptr[v] + d]] += 1.0 / d
        else:
            Q[v, :] = 1.0 / n
    return (eps / n) * np.ones((n, n)) + (1.0 - eps) * Q


def exact_pagerank(graph: CSRGraph, eps: float) -> np.ndarray:
    """Exact stationary distribution of P via eigen-solve (test oracle only)."""
    P = transition_matrix(graph, eps)
    w, V = np.linalg.eig(P.T)
    i = int(np.argmin(np.abs(w - 1.0)))
    pi = np.real(V[:, i])
    pi = np.abs(pi)
    return pi / pi.sum()
