"""SIMPLE-PAGERANK-ALGORITHM (Algorithm 1) driver.

K = c*log(n) PageRank random walks from every node, terminated at the first
eps-reset; pi_tilde_v = zeta_v * eps / (nK). Engine selectable:
  * "walks"  — TPU-native walk-array engine (default, fast)
  * "counts" — faithful count-message engine (CONGEST reference)
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import engine_counts, engine_walks
from repro.core.accounting import CongestReport, RoundTrace, default_bandwidth
from repro.core.estimator import pagerank_from_visits
from repro.core.graph import CSRGraph


@dataclasses.dataclass
class PageRankResult:
    pi: jnp.ndarray
    zeta: jnp.ndarray
    walks_per_node: int
    eps: float
    logical_rounds: int
    report: Optional[CongestReport] = None

    def congest_rounds(self) -> Optional[int]:
        return self.report.congest_rounds if self.report else None


def walks_per_node_for(n: int, eps: float, delta_prime: float = 1.0) -> int:
    """K = c*log n with c = 2/(delta' * eps)  (Section 3.2)."""
    c = 2.0 / (delta_prime * eps)
    return max(1, int(math.ceil(c * math.log(max(n, 2)))))


def simple_pagerank(graph: CSRGraph, eps: float, *, walks_per_node: int | None = None,
                    key: jnp.ndarray | None = None, engine: str = "walks",
                    traced: bool = False, bandwidth_bits: int | None = None,
                    use_pallas: bool = False) -> PageRankResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    K = walks_per_node or walks_per_node_for(graph.n, eps)
    traces: List[RoundTrace] = []

    if engine == "counts":
        state, traces = engine_counts.run_traced(graph, eps, K, key,
                                                 use_pallas=use_pallas)
        zeta, rounds = state.zeta, int(state.round)
    elif engine == "walks" and traced:
        state, traces = engine_walks.run_traced(graph, eps, K, key,
                                                use_pallas=use_pallas)
        zeta, rounds = state.zeta, int(state.round)
    elif engine == "walks":
        state = engine_walks.run(graph, eps, K, key, use_pallas=use_pallas)
        zeta, rounds = state.zeta, int(state.round)
    else:
        raise ValueError(f"unknown engine {engine!r}")

    report = None
    if traces:
        report = CongestReport(traces=traces, n=graph.n,
                               bandwidth_bits=bandwidth_bits or default_bandwidth(graph.n))
    pi = pagerank_from_visits(zeta, graph.n, K, eps)
    return PageRankResult(pi=pi, zeta=zeta, walks_per_node=K, eps=eps,
                          logical_rounds=rounds, report=report)
