"""Power-iteration PageRank — the traditional baseline the paper argues
against in the distributed setting. Implemented as a sharded sparse push:

    pi_{t+1} = eps/n + (1-eps) * (Q^T pi_t + dangling_mass/n)

The push over the CSR edge list is a segment-sum; the hot loop can run
through the `segment_spmv` Pallas kernel (TPU one-hot-MXU tiling) or the
pure-jnp path (oracle / CPU).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CSRGraph


def spmv_push(graph: CSRGraph, x: jnp.ndarray, *, use_pallas: bool = False) -> jnp.ndarray:
    """y = Q^T x  where Q is the row-stochastic out-edge matrix.

    Each edge (v -> u) pushes x[v]/deg(v) into y[u].
    """
    src = graph.edge_src()
    contrib = x[src] / graph.out_deg[src].astype(x.dtype)
    if use_pallas:
        from repro.kernels.segment_spmv import ops as spmv_ops

        return spmv_ops.segment_spmv(contrib, graph.col_idx, graph.n)
    return jax.ops.segment_sum(contrib, graph.col_idx, num_segments=graph.n)


@partial(jax.jit, static_argnames=("graph_n", "max_iters", "use_pallas"))
def _power_iterate(row_ptr, col_idx, out_deg, edge_src, graph_n: int, eps: float,
                   tol: float, max_iters: int, use_pallas: bool):
    deg_f = jnp.maximum(out_deg, 1).astype(jnp.float32)
    dangling = (out_deg == 0)

    def push(x):
        contrib = x[edge_src] / deg_f[edge_src]
        if use_pallas:
            from repro.kernels.segment_spmv import ops as spmv_ops

            y = spmv_ops.segment_spmv(contrib, col_idx, graph_n)
        else:
            y = jax.ops.segment_sum(contrib, col_idx, num_segments=graph_n)
        dang_mass = jnp.sum(jnp.where(dangling, x, 0.0))
        return y + dang_mass / graph_n

    def cond(state):
        _, err, it = state
        return jnp.logical_and(err > tol, it < max_iters)

    def body(state):
        x, _, it = state
        x_new = eps / graph_n + (1.0 - eps) * push(x)
        err = jnp.abs(x_new - x).sum()
        return x_new, err, it + 1

    x0 = jnp.full((graph_n,), 1.0 / graph_n, dtype=jnp.float32)
    x, err, iters = jax.lax.while_loop(cond, body, (x0, jnp.inf, jnp.int32(0)))
    return x, err, iters


def power_iteration(graph: CSRGraph, eps: float, *, tol: float = 1e-7,
                    max_iters: int = 10_000, use_pallas: bool = False
                    ) -> Tuple[jnp.ndarray, float, int]:
    """Returns (pi, final_l1_delta, iterations)."""
    x, err, iters = _power_iterate(
        graph.row_ptr, graph.col_idx, graph.out_deg, graph.edge_src(),
        graph.n, float(eps), float(tol), int(max_iters), bool(use_pallas))
    return x, float(err), int(iters)
