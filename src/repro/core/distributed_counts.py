"""Count-aggregated distributed engine — Lemma 1 applied to our own wire.

The walk-array engine (distributed.py) routes every cross-shard walk as its
own int32 position: payload ∝ moving walks. The paper's core insight
(Lemma 1) says walks are anonymous — only *counts* per edge matter. This
engine keeps per-vertex coupon counts as shard state and exchanges
(dst_vertex, count) pairs, so the all_to_all payload is bounded by the
number of CUT EDGES with traffic this round — **independent of how many
walks run in parallel**.

Payload bound is static: lane capacity per (src,dst) shard pair =
|edges crossing that pair| (precomputed from the partition), so there is no
overflow path at all (the walk engine needs waiting/carry-over logic).

Per super-step, per shard:
  1. terminations  ~ Binomial(counts, eps)                (paper line 4-5)
  2. survivors split over out-edges via the conditional-binomial chain
     (exact Multinomial — same sampler as engine_counts)
  3. per-edge counts aggregated per destination *vertex* and exchanged with
     one all_to_all of (vertex, count) lanes               (Lemma 1 wire)
  4. arrivals summed into counts + visit counters zeta

Steps 1-2 run through the shared degree-bucketed aggregate sampler
(`core/aggregate_sampler`): rows are grouped by power-of-two degree
buckets via a static permutation computed at shard time (memoized like
the step makers), and each bucket's chain scans the bucket width instead
of the global max degree — per-round sampler FLOPs ~ sum_v deg(v), not
n_loc * max_deg. Sampler RNG contract: draws are a pure counter-based
function of (per-round key words, global row id = padded vertex id, slot
index) — see `kernels/multinomial_rows/_math` — so rows sample
independently of bucket order and blocking, `use_pallas` (kernel vs jnp
ref) never changes the draws, and checkpoint replay stays bit-exact.
The super-step is two jitted programs, sample then exchange, so the
driver can clock the sampler separately: per-round sampler microseconds
and per-bucket occupancy land in the host telemetry dict next to the
wire counters (`sampler_us`, `occupancy`).
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import lru_cache, partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.aggregate_sampler import (BucketLayout, build_layout_sharded,
                                          bucketize_adjacency, flatten_moves,
                                          sample_buckets)
from repro.core.distributed import AXIS, shard_map
from repro.core.estimator import pagerank_from_visits
from repro.core.graph import CSRGraph
from repro.core.routing import entry_nbytes, lane_slots
from repro.checkpoint import LayoutSpec
from repro.kernels import resolve_use_pallas
from repro.kernels.multinomial_rows._math import key_words
from repro.runtime import Stage, StagedState, StageSchedule, run_staged


@dataclasses.dataclass(frozen=True)
class ShardedPaddedGraph:
    """Per-shard padded adjacency with static cross-shard lane bounds and
    the degree-bucketed sampler layout (see `core/aggregate_sampler`)."""

    n: int
    n_pad: int
    n_loc: int
    shards: int
    max_deg: int
    nbr: jnp.ndarray        # [P, n_loc, max_deg] global dst (self-padded)
    valid: jnp.ndarray      # [P, n_loc, max_deg]
    deg: jnp.ndarray        # [P, n_loc]
    lane_cap: int           # max edges crossing any (src,dst) shard pair
    layout: BucketLayout    # shard-uniform bucket caps/widths (static)
    bperm: jnp.ndarray      # [P, layout.total_rows] bucket-grouped local
                            # row ids (-1 = padding slot)
    bnbr: jnp.ndarray       # [P, layout.total_edges] flat bucketed dst


def shard_graph_padded(graph: CSRGraph, shards: int, *,
                       bucketed: bool = True) -> ShardedPaddedGraph:
    n_loc = math.ceil(graph.n / shards)
    n_pad = n_loc * shards
    md = max(graph.max_out_deg, 1)
    rp = np.asarray(graph.row_ptr)
    col = np.asarray(graph.col_idx)
    degs = np.asarray(graph.out_deg)
    nbr = np.tile(np.arange(n_pad, dtype=np.int32)[:, None] * 0, (1, md))
    nbr = np.zeros((n_pad, md), np.int32)
    valid = np.zeros((n_pad, md), bool)
    for v in range(graph.n):
        d = degs[v]
        nbr[v, :d] = col[rp[v]:rp[v] + d]
        valid[v, :d] = True
    deg_pad = np.concatenate([degs, np.zeros(n_pad - graph.n, np.int32)])
    # static lane bound: edges from shard p to shard q
    cut = np.zeros((shards, shards), np.int64)
    owner_of = lambda v: v // n_loc
    src_owner = np.repeat(np.arange(graph.n) // n_loc, degs)
    dst_owner = col // n_loc
    np.add.at(cut, (src_owner, dst_owner), 1)
    # lanes hold (vertex,count) pairs: at most min(cut, n_loc) distinct
    lane_cap = int(min(cut.max(), n_loc)) or 1
    deg_sh = deg_pad.reshape(shards, n_loc)
    nbr_sh = nbr.reshape(shards, n_loc, md)
    layout, bperm = build_layout_sharded(deg_sh, md, bucketed=bucketed)
    bnbr = bucketize_adjacency(nbr_sh, bperm, layout)
    return ShardedPaddedGraph(
        n=graph.n, n_pad=n_pad, n_loc=n_loc, shards=shards, max_deg=md,
        nbr=jnp.asarray(nbr_sh),
        valid=jnp.asarray(valid.reshape(shards, n_loc, md)),
        deg=jnp.asarray(deg_sh),
        lane_cap=lane_cap,
        layout=layout, bperm=jnp.asarray(bperm), bnbr=jnp.asarray(bnbr))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CountDistState:
    counts: jnp.ndarray   # [P, n_loc]
    zeta: jnp.ndarray     # [P, n_loc]
    key: jnp.ndarray      # [P, 2]
    round: jnp.ndarray


def _sample_step(bperm, deg, counts, key, *, eps: float, n_loc: int,
                 shards: int, layout: BucketLayout, use_pallas: bool):
    """Program 1 of the super-step: the degree-bucketed aggregate draw.

    Pure per-shard compute (no collectives beyond the telemetry psums), so
    the driver can clock it separately — its wall time is the engine's
    `sampler_us` telemetry. Returns the flat per-edge counts aligned with
    `ShardedPaddedGraph.bnbr`, the advanced key, global per-bucket
    occupancy, and the (must-be-zero) conservation residual.
    """
    bperm, deg, counts, key = bperm[0], deg[0], counts[0], key[0]
    shard_id = jax.lax.axis_index(AXIS)
    key, k_sample = jax.random.split(key)
    # rid: globally-unique padded vertex id -> draws independent per vertex
    rid = shard_id * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
    samples, occ, residual = sample_buckets(
        counts, deg, rid, key_words(k_sample), bperm, layout,
        eps=eps, use_pallas=use_pallas)
    flat_T = flatten_moves(samples)
    occ = jax.lax.psum(occ, AXIS)
    residual = jax.lax.psum(residual, AXIS)
    return flat_T[None], key[None], occ, residual


def _exchange_step(bnbr, flat_T, zeta, *, n_loc: int, shards: int,
                   lane_cap: int, packed: bool = True):
    """Program 2 of the super-step: aggregate per destination vertex and
    run the Lemma-1 (vertex, count) lane exchange."""
    bnbr, flat_T, zeta = bnbr[0], flat_T[0], zeta[0]
    shard_id = jax.lax.axis_index(AXIS)

    flat_dst = bnbr
    owner = flat_dst // n_loc
    local_mask = owner == shard_id
    # local arrivals: direct segment-sum
    arrive = jax.ops.segment_sum(
        jnp.where(local_mask, flat_T, 0),
        jnp.clip(flat_dst - shard_id * n_loc, 0, n_loc - 1),
        num_segments=n_loc)

    # cross-shard: aggregate counts per destination vertex, then lane-pack
    # (vertex, count) per target shard. Aggregate first so the lane bound
    # is #distinct vertices, not #edges.
    remote_T = jnp.where(local_mask, 0, flat_T)
    per_vertex = jax.ops.segment_sum(remote_T, flat_dst,
                                     num_segments=n_loc * shards)
    vid = jnp.arange(n_loc * shards, dtype=jnp.int32)
    if packed:
        # 4B lanes: (local vid:16b | count:15b) — 15-bit count keeps the
        # packed int32 non-negative (-1 stays the empty sentinel); larger
        # counts spill into a second entry for the same vertex.
        CMAX = 32767
        spill = jnp.maximum(per_vertex - CMAX, 0)
        c_main = jnp.minimum(per_vertex, CMAX)
        vid2 = jnp.concatenate([vid, vid])
        cnt2 = jnp.concatenate([c_main, jnp.minimum(spill, CMAX)])
    else:
        vid2 = vid
        cnt2 = per_vertex
    has = cnt2 > 0
    v_owner = vid2 // n_loc
    ok, lane_idx = lane_slots(v_owner, has, shards, lane_cap)
    if packed:
        local_vid = (vid2 % n_loc).astype(jnp.int32)
        payload = local_vid | (cnt2.astype(jnp.int32) << 16)
        lanes = (jnp.full((shards * lane_cap,), -1, jnp.int32)
                 .at[lane_idx].set(jnp.where(ok, payload, -1), mode="drop"))
        overflow = jax.lax.psum(jnp.sum(jnp.where(has & ~ok, cnt2, 0)), AXIS)
        recv = jax.lax.all_to_all(lanes.reshape(shards, lane_cap), AXIS,
                                  split_axis=0, concat_axis=0,
                                  tiled=True).reshape(-1)
        got = recv >= 0
        rv = recv & 0xFFFF
        rc = jnp.where(got, recv >> 16, 0)
        arrive = arrive + jax.ops.segment_sum(
            rc, jnp.where(got, rv, 0), num_segments=n_loc)
        wire_entries = jnp.sum(lanes >= 0)
        # dtype-derived, not a magic constant: one packed int32 lane column
        bytes_per = entry_nbytes(lanes)
    else:
        lanes_v = (jnp.full((shards * lane_cap,), -1, jnp.int32)
                   .at[lane_idx].set(jnp.where(ok, vid2, -1), mode="drop"))
        lanes_c = (jnp.zeros((shards * lane_cap,), jnp.int32)
                   .at[lane_idx].set(jnp.where(ok, cnt2, 0), mode="drop"))
        overflow = jax.lax.psum(jnp.sum(jnp.where(has & ~ok, cnt2, 0)), AXIS)
        recv_v = jax.lax.all_to_all(lanes_v.reshape(shards, lane_cap), AXIS,
                                    split_axis=0, concat_axis=0,
                                    tiled=True).reshape(-1)
        recv_c = jax.lax.all_to_all(lanes_c.reshape(shards, lane_cap), AXIS,
                                    split_axis=0, concat_axis=0,
                                    tiled=True).reshape(-1)
        got = recv_v >= 0
        arrive = arrive + jax.ops.segment_sum(
            jnp.where(got, recv_c, 0),
            jnp.clip(recv_v - shard_id * n_loc, 0, n_loc - 1),
            num_segments=n_loc)
        wire_entries = jnp.sum(lanes_v >= 0)
        bytes_per = entry_nbytes(lanes_v, lanes_c)

    new_counts = arrive
    new_zeta = zeta + arrive
    active = jax.lax.psum(jnp.sum(new_counts), AXIS)
    a2a_entries = jax.lax.psum(wire_entries, AXIS)
    a2a_bytes = a2a_entries * bytes_per
    return (new_counts[None], new_zeta[None], active, a2a_entries,
            a2a_bytes, overflow)


# memoized like the other engines' step makers: the graph's static layout
# (n_loc/shards/bucket layout/lane_cap) is the cache key, not the array
# payload, so repeat runs over same-shaped graphs skip recompilation
@lru_cache(maxsize=64)
def make_count_superstep(mesh: Mesh, eps: float, *, n_loc: int, shards: int,
                         layout: BucketLayout, lane_cap: int,
                         packed: bool = True, use_pallas: bool = False):
    """Returns (sample, exchange): the two jitted halves of the super-step.
    The driver times `sample` (block_until_ready) for `sampler_us`."""
    sample_sh = shard_map(
        partial(_sample_step, eps=eps, n_loc=n_loc, shards=shards,
                layout=layout, use_pallas=use_pallas),
        mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(), P()),
    )
    exch_sh = shard_map(
        partial(_exchange_step, n_loc=n_loc, shards=shards,
                lane_cap=lane_cap, packed=packed),
        mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(), P(), P(), P()),
    )

    @jax.jit
    def sample(bperm, deg, state: CountDistState):
        return sample_sh(bperm, deg, state.counts, state.key)

    @jax.jit
    def exchange(bnbr, flat_T, key, state: CountDistState):
        counts, zeta, active, entries, a2a, overflow = exch_sh(
            bnbr, flat_T, state.zeta)
        return (CountDistState(counts=counts, zeta=zeta, key=key,
                               round=state.round + 1),
                active, entries, a2a, overflow)

    return sample, exchange


def _count_layouts(n: int):
    """Elastic layout schema for the counts engine's single stage — shared
    by the engine and the CONGEST auditor's schema lint."""
    return dict(counts=LayoutSpec(kind="vertex", n=n),
                zeta=LayoutSpec(kind="vertex", n=n),
                key=LayoutSpec(kind="replicated_key"),
                round=LayoutSpec(kind="replicated"))


@dataclasses.dataclass
class CountDistResult:
    zeta: jnp.ndarray
    pi: jnp.ndarray
    rounds: int
    a2a_bytes_total: int
    overflow: int
    shards: int
    lane_cap: int
    a2a_entries_total: int = 0   # routed (vertex, count) lane entries
    restarts: int = 0            # supervisor recoveries (fault injection)
    checkpoints_written: int = 0
    sampler_us: float = 0.0      # total wall time inside the sample program
    occupancy: tuple = ()        # per-bucket rows-with-coupons, summed over
                                 # rounds and shards (len = #buckets)
    residual: int = 0            # conservation leak — must stay 0


def distributed_pagerank_counts(graph: CSRGraph, eps: float,
                                walks_per_node: int, key: jnp.ndarray, *,
                                mesh: Optional[Mesh] = None,
                                packed: bool = True,
                                max_rounds: int = 100_000,
                                checkpoint_dir: Optional[str] = None,
                                fail_at: Optional[Sequence[int]] = None,
                                checkpoint_every: int = 10,
                                max_restarts: int = 16,
                                resume: bool = False,
                                use_pallas=None,
                                bucketed: bool = True) -> CountDistResult:
    """Count-aggregated Algorithm 1 across all devices of `mesh`.

    With `checkpoint_dir`/`fail_at` set, the super-step loop runs under the
    checkpoint-restart supervisor (single-stage schedule): recovery from an
    injected failure replays the identical trajectory (state includes the
    PRNG keys), so the recovered run is bit-exact. `bucketed=False` keeps
    the single-bucket max_deg-wide sampler layout (pre-bucketing shape,
    for benchmarking); the draws themselves are layout-independent.

    Snapshots are mesh-size-agnostic: the round key is REPLICATED across
    shards (every shard advances the same stream; draws are distinguished
    purely by the counter-based global vertex id, which is mesh-size
    independent), and the state declares its layout schema, so
    `resume=True` onto a mesh with a different device count re-layouts
    the snapshot and continues BIT-EXACTLY — same zeta/pi as the
    uninterrupted run at the original shard count."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    use_pallas = resolve_use_pallas(use_pallas)
    shards = mesh.devices.size
    sg = shard_graph_padded(graph, shards, bucketed=bucketed)
    spec = NamedSharding(mesh, P(AXIS))

    counts0 = np.zeros((shards, sg.n_loc), np.int32)
    counts0.reshape(-1)[: graph.n] = walks_per_node
    # REPLICATED round key: every shard splits the same stream, draws are
    # distinguished only by the counter-based global vertex id — so the
    # trajectory is a pure function of (seed, graph), not the mesh size
    keys = jnp.tile(jnp.asarray(key)[None], (shards, 1))
    deg = jax.device_put(sg.deg, spec)
    bperm = jax.device_put(sg.bperm, spec)
    bnbr = jax.device_put(sg.bnbr, spec)

    sample, exchange = make_count_superstep(
        mesh, float(eps), n_loc=sg.n_loc, shards=sg.shards,
        layout=sg.layout, lane_cap=sg.lane_cap, packed=packed,
        use_pallas=use_pallas)

    def _step(ms: StagedState):
        a = ms.arrays
        st = CountDistState(counts=a["counts"], zeta=a["zeta"],
                            key=a["key"], round=a["round"])
        t0 = time.perf_counter()
        flat_T, key2, occ, residual = sample(bperm, deg, st)
        jax.block_until_ready(flat_T)
        t1 = time.perf_counter()
        st, active, entries, a2a, ovf = exchange(bnbr, flat_T, key2, st)
        a.update(counts=st.counts, zeta=st.zeta, key=st.key, round=st.round)
        h = ms.host
        active_i, entries_i, a2a_i, ovf_i, occ_v, res_i = jax.device_get(
            (active, entries, a2a, ovf, occ, residual))
        h["rounds"] += 1
        h["a2a"] += int(a2a_i)
        h["a2a_entries"] += int(entries_i)
        h["overflow"] += int(ovf_i)
        h["sampler_us"] += (t1 - t0) * 1e6
        h["occupancy"] = [int(x) + int(y)
                          for x, y in zip(h["occupancy"], occ_v)]
        h["residual"] += int(res_i)
        return ms, int(active_i) == 0 or h["rounds"] >= max_rounds

    schedule = StageSchedule([Stage("counts", _step)])
    ms = StagedState(
        stage=schedule.first_stage,
        arrays=dict(counts=jax.device_put(jnp.asarray(counts0), spec),
                    zeta=jax.device_put(jnp.asarray(counts0), spec),
                    key=jax.device_put(keys, spec),
                    round=jnp.int32(0)),
        host=dict(rounds=0, a2a=0, a2a_entries=0, overflow=0, sampler_us=0.0,
                  occupancy=[0] * len(sg.layout.caps), residual=0),
        layouts={"counts": _count_layouts(graph.n)},
        shards=shards)

    def _put(name, arr):
        return (jnp.asarray(arr) if name == "round"
                else jax.device_put(jnp.asarray(arr), spec))

    ms, restarts, checkpoints_written = run_staged(
        schedule, ms, _put, checkpoint_dir=checkpoint_dir, fail_at=fail_at,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts,
        resume=resume, max_rounds=max_rounds + 1,
        tmp_prefix="prcnt_ckpt_")

    zeta = ms.arrays["zeta"].reshape(-1)[: graph.n]
    pi = pagerank_from_visits(zeta, graph.n, walks_per_node, eps)
    return CountDistResult(zeta=zeta, pi=pi, rounds=ms.host["rounds"],
                           a2a_bytes_total=ms.host["a2a"],
                           overflow=ms.host["overflow"], shards=shards,
                           lane_cap=sg.lane_cap,
                           a2a_entries_total=ms.host["a2a_entries"],
                           restarts=restarts,
                           checkpoints_written=checkpoints_written,
                           sampler_us=float(ms.host["sampler_us"]),
                           occupancy=tuple(ms.host["occupancy"]),
                           residual=int(ms.host["residual"]))


def audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float = 0.2,
               walks_per_node: int = 2, packed: bool = True,
               use_pallas: bool = False, bucketed: bool = True):
    """CONGEST-auditor spec: the exact memoized step programs the engine
    runs (same cache keys => same traced jaxprs), the declared wire budget
    for the single (vertex, count) all_to_all, and the elastic schema."""
    from repro.core.accounting import (EngineAuditSpec, ExchangeSite,
                                       StageProgram)
    shards = int(mesh.devices.size)
    sg = shard_graph_padded(graph, shards, bucketed=bucketed)
    n_loc = sg.n_loc
    sample, exchange = make_count_superstep(
        mesh, float(eps), n_loc=n_loc, shards=shards, layout=sg.layout,
        lane_cap=sg.lane_cap, packed=packed, use_pallas=use_pallas)
    sds = jax.ShapeDtypeStruct
    i32, u32 = jnp.int32, jnp.uint32
    state = CountDistState(counts=sds((shards, n_loc), i32),
                           zeta=sds((shards, n_loc), i32),
                           key=sds((shards, 2), u32),
                           round=sds((), i32))
    bperm = sds((shards, sg.bperm.shape[1]), sg.bperm.dtype)
    deg = sds((shards, n_loc), sg.deg.dtype)
    bnbr = sds((shards, sg.bnbr.shape[1]), sg.bnbr.dtype)
    flat_T = sds((shards, sg.layout.total_edges), i32)
    key = sds((shards, 2), u32)
    width = 4 if packed else 8
    site = ExchangeSite(
        site="counts", entry_nbytes=width,
        lane_entries=shards * sg.lane_cap,
        budget_entries=shards * n_loc,
        budget_formula=("P * min(cut_max, n_loc) distinct (vertex, count) "
                        "cells <= P * n_loc"),
        wire_class="count",
        note="Lemma 1: lane bound counts distinct destination vertices, "
             "never walk multiplicity W")
    progs = [
        StageProgram(stage="counts", program="sample", fn=sample,
                     example_args=(bperm, deg, state), sites=(),
                     count_bound=graph.n * walks_per_node),
        StageProgram(stage="counts", program="exchange", fn=exchange,
                     example_args=(bnbr, flat_T, key, state), sites=(site,),
                     count_bound=graph.n * walks_per_node),
    ]
    return EngineAuditSpec(
        engine="counts", programs=progs,
        stage_arrays={"counts": ("counts", "zeta", "key", "round")},
        layouts={"counts": _count_layouts(graph.n)},
        meta=dict(shards=shards, n=graph.n, lane_cap=sg.lane_cap,
                  packed=packed, walks_per_node=walks_per_node))
