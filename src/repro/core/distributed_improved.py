"""Multi-device IMPROVED-PAGERANK engine — shard_map realization of
Algorithm 2 on the vertex-partitioned `ShardedGraph`.

The single-device `improved_pagerank.py` holds the whole coupon pool and
every trajectory in one address space; this engine is the CONGEST-faithful
TPU-pod version: vertices are partitioned into contiguous shards (one per
mesh device) and every exchange is a fixed-capacity `all_to_all` built from
the shared lane machinery in `routing.py`. Payloads are count-aggregated
per Lemma 1: walks are anonymous, so everything that moves between shards
travels as (vertex, count) pairs — the wire volume is bounded by the number
of *distinct* (vertex, outcome) pairs, independent of how many walks move.

Phase 1 — short-walk pre-computation. Shard p owns the coupons of its
  vertices: vertex v gets pool_size(v) = d(v)*eta coupons (Lemma 2 sizing,
  see `improved_pagerank.coupon_pool_sizes`), each a PageRank walk given
  exactly lambda = ceil(sqrt(log n)) step opportunities. Coupons never
  migrate; slot s of shard p's pool table is its identity. Each round is
  one count-aggregated round trip:

    request — every home shard histograms its live coupons' current
      vertices and ships per-vertex counts to the owners
      (`route_counts(by_source=True)`, 8 B/entry);
    sample  — the owner draws, independently for every (home, vertex)
      row, a Binomial(c, eps) termination count (a dangling vertex
      terminates the whole row) and splits the survivors over the
      out-edges with a conditional-binomial multinomial — the aggregate
      of c iid walk steps, never c individual steps. The draws run
      through the shared degree-bucketed aggregate sampler
      (`core/aggregate_sampler`): rows grouped by power-of-two degree
      buckets via a static shard-time permutation, each bucket's chain
      scanning the bucket width instead of the global max degree, so
      Phase-1 sampler FLOPs are ~ sum_v deg(v) per round. RNG contract:
      counter-based draws keyed on (round key words, globally-unique row
      id, slot) — see `kernels/multinomial_rows/_math` — so the results
      are independent of bucket layout and of `use_pallas`, and replay
      stays bit-exact. The sample program is split out of the round so
      the driver can clock it (`sampler_us`, `p1_occupancy` telemetry);
    reply   — nonzero (vertex, outcome-class, count) cells go back to the
      home shard (12 B/entry); outcome class 0 is "terminated", class j
      is "moved to out-edge j" carrying the destination vertex id;
    assign  — the home shard assigns its coupons at vertex v to the
      returned outcome slots by a uniformly-random permutation (random
      priorities + stable rank within the vertex group). A multiset of
      iid outcomes dealt out in uniform-random order IS an iid draw per
      coupon, so every coupon still walks the exact eps-reset chain.

  The per-coupon move is recorded in a home-local trajectory table
  `traj[slot, t]` — this is what Phase 3 counts, so no replay is needed.

Phase 2 — stitching. The n*K long walks are anonymous too ("which coupon
  did walk w use" is never needed — coupons are iid), so the engine keeps
  only per-vertex walk *counts*. Each stitch superstep allocates, at every
  owned vertex, the next `min(walks_here, pool_left)` unused coupons
  (natural-order consumption — distributionally identical to
  uniform-without-replacement because coupons are iid), marks them used,
  retires walks whose coupon recorded an eps-reset, and ships the rest as
  per-destination counts (`route_counts`, 8 B/entry). Walks at an
  exhausted pool (eta undersized — the paper's whp bound violated)
  accumulate in a per-vertex tail count for the naive fallback.

Phase 3 — counting. One histogram of the used coupons' home-local
  trajectories plus ONE `route_counts` exchange lands every visit at its
  owner shard: the paper's "destinations report their ID" step collapses
  to a single aggregated round (the old implementation re-ran the whole
  Phase-1 schedule as a deterministic replay; the trajectory table makes
  that — and its per-walk wire — unnecessary). Tail walks then finish
  naively through the Algorithm 1 superstep (`distributed._make_superstep`),
  counting arrivals into the same sharded zeta; the estimator
  pi = zeta * eps/(nK) is computed on the host in float64
  (`estimator.pagerank_from_visits`).

Static shapes throughout; count lanes are sized so overflow is
*structurally impossible* (`route_counts` caps lanes at n_loc distinct
vertices; Phase-1 replies at min(n_loc*(max_deg+1), S_loc_pad) distinct
cells), so `dropped` stays 0 by construction — only the naive tail keeps
the Algorithm-1 `cap >= 2*W/P + P*route_cap` sizing rule.

The phases only ever see a per-node pool-size vector, so the whole driver
lives in the budget-policy-agnostic `_run_three_phase`; this module's
public `distributed_improved_pagerank` feeds it Lemma-2 degree-proportional
pools, and `distributed_directed.distributed_directed_pagerank` feeds it
the Section-5 uniform/LOCAL pools — count aggregation removed the
worst-case per-walk buffers that engine used to need.

`use_pallas` routes the histograms, the count reductions, and the tail's
walk advancement through the Pallas kernels in `repro.kernels`
(bit-identical decision logic, interpret mode off-TPU); `None` defers to
the REPRO_USE_PALLAS env var.

Fault tolerance — the driver is a *checkpointable phase-machine*: each
phase (phase1, phase2, phase3, tail) is a named `runtime.Stage` whose
snapshot is the stage's device buffers (coupon tables, trajectory table,
walk counts, the `used` bitmap) plus the host accumulators (wire/trace
telemetry, round counters) as a pytree of arrays. With `checkpoint_dir`/
`fail_at` set, the `runtime.Supervisor` drives the composed
`StageSchedule`: a killed run resumes mid-phase from the latest
stage-tagged snapshot and — because every stage is deterministic given its
buffers and keys — produces bit-identical `zeta`/`pi` and telemetry vs an
unfailed run.
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import lru_cache, partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.accounting import CongestReport, RoundTrace, default_bandwidth
from repro.core.aggregate_sampler import (build_layout_sharded,
                                          sample_buckets, scatter_cells)
from repro.core.distributed import (AXIS, DistState, _make_superstep,
                                    shard_graph, shard_map)
from repro.core.estimator import pagerank_from_visits
from repro.core.graph import CSRGraph
from repro.core.improved_pagerank import coupon_pool_sizes
from repro.core.routing import (entry_nbytes, exchange_stacked, lane_slots,
                                pack_lanes, route_counts, vertex_histogram)
from repro.checkpoint import LayoutSpec
from repro.core.simple_pagerank import walks_per_node_for
from repro.kernels import resolve_use_pallas
from repro.kernels.multinomial_rows._math import key_words
from repro.runtime import Stage, StagedState, StageSchedule, run_staged

_INT32_MAX = 2 ** 31 - 1


# ---------------------------------------------------------------------------
# Phase 1: count-aggregated short-walk pre-computation
# ---------------------------------------------------------------------------

def _p1_request(pos, alive, *, n_loc: int, shards: int, use_pallas: bool,
                count_bound: Optional[int] = None):
    """Phase-1 program 1 (request): per-vertex live-coupon counts to the
    owners. Output row layout: c[home * n_loc + v] = coupons of `home`
    currently at owned vertex v."""
    pos, alive = pos[0], alive[0]
    shard_id = jax.lax.axis_index(AXIS)
    n_pad = shards * n_loc
    req = vertex_histogram(pos, alive > 0, n_pad, use_pallas=use_pallas)
    c_by_home, req_entries, req_bytes = route_counts(
        req, axis=AXIS, shard_id=shard_id, n_loc=n_loc, shards=shards,
        by_source=True, use_pallas=use_pallas, count_bound=count_bound)
    c = c_by_home.reshape(-1)               # [P*n_loc], row = home*n_loc + v
    req_entries = jax.lax.psum(req_entries, AXIS)
    req_bytes = jax.lax.psum(req_bytes, AXIS)
    return c[None], req_entries, req_bytes


def _p1_sample(bperm, dg, c, key, *, eps: float, n_loc: int, shards: int,
               md: int, layout, use_pallas: bool):
    """Phase-1 program 2 (sample): the owner draws, independently for every
    (home, vertex) row, the fused Binomial(eps) termination + conditional-
    binomial edge split through the shared degree-bucketed sampler (a
    dangling row terminates whole). Pure per-shard compute — the driver
    clocks it for `sampler_us`. Returns the dense home-major outcome cells
    f_cnt[(home*n_loc + v)*(md+1) + class] plus the advanced key, the
    assignment key, per-bucket occupancy, and the conservation residual.

    RNG contract: every draw is a pure counter-based function of the
    per-round key words, rid = owner*n_pad + home*n_loc + v (globally
    unique per row), and the slot index — independent of bucket order,
    so bucketed/unbucketed layouts and kernel/ref paths are bit-identical.
    """
    bperm, dg, c, key = bperm[0], dg[0], c[0], key[0]
    shard_id = jax.lax.axis_index(AXIS)
    n_pad = shards * n_loc
    key, k_sample, k_perm = jax.random.split(key, 3)

    # tile the local bucket permutation across homes, bucket-major: bucket
    # b's tiled rows are every home's bucket-b rows, offset by home*n_loc
    # (-1 padding slots preserved). Matches layout.tile(shards).
    offs = jnp.arange(shards, dtype=jnp.int32)[:, None] * n_loc
    parts = []
    for start, cap in zip(layout.row_starts, layout.caps):
        pb = bperm[start:start + cap]
        parts.append(jnp.where(pb[None, :] < 0, -1,
                               offs + pb[None, :]).reshape(-1))
    perm_t = jnp.concatenate(parts)
    layout_t = layout.tile(shards)

    deg_row = jnp.tile(dg, shards)
    rid = shard_id * n_pad + jnp.arange(n_pad, dtype=jnp.int32)
    samples, occ, residual = sample_buckets(
        c, deg_row, rid, key_words(k_sample), perm_t, layout_t,
        eps=eps, use_pallas=use_pallas)
    f_cnt = scatter_cells(samples, layout_t, md)
    occ = jax.lax.psum(occ, AXIS)
    residual = jax.lax.psum(residual, AXIS)
    return f_cnt[None], key[None], k_perm[None], occ, residual


def _p1_assign(rp, ci, pos, alive, traj, f_cnt, k_perm, t, *,
               n_loc: int, shards: int, md: int, rep_cap: int,
               S_loc_pad: int):
    """Phase-1 program 3 (reply + assign): route the nonzero outcome cells
    back to the home shards and deal them out to the coupons by a
    uniform-random within-vertex permutation (see module docstring)."""
    rp, ci, pos, alive, traj, f_cnt, k_perm = (
        rp[0], ci[0], pos[0], alive[0], traj[0], f_cnt[0], k_perm[0])
    shard_id = jax.lax.axis_index(AXIS)
    n_pad = shards * n_loc
    C = S_loc_pad + 1
    cells = n_loc * (md + 1)
    elig = alive > 0

    eidx = jnp.clip(rp[:n_loc, None] + jnp.arange(md)[None, :], 0,
                    ci.shape[0] - 1)
    edge_dst = ci[eidx]                     # [n_loc, md] global dst per edge
    dst = jnp.concatenate(
        [jnp.full((shards * n_loc, 1), -2, jnp.int32),   # class 0: reset
         jnp.tile(edge_dst, (shards, 1))], axis=1)
    vid = jnp.tile(shard_id * n_loc + jnp.arange(n_loc, dtype=jnp.int32),
                   shards)

    # ---- reply: nonzero (vertex, class, count) cells to the home ----
    f_vid = jnp.repeat(vid, md + 1)
    f_dst = dst.reshape(-1)
    home = jnp.arange(shards * cells, dtype=jnp.int32) // cells
    remote = (f_cnt > 0) & (home != shard_id)
    sendable, flat_idx = lane_slots(home, remote, shards, rep_cap)
    l_vid = pack_lanes(flat_idx, f_vid, sendable, shards, rep_cap, fill=-1)
    l_dst = pack_lanes(flat_idx, f_dst, sendable, shards, rep_cap, fill=0)
    l_cnt = pack_lanes(flat_idx, f_cnt, sendable, shards, rep_cap, fill=0)
    r_vid, r_dst, r_cnt = exchange_stacked([l_vid, l_dst, l_cnt], AXIS,
                                           shards, rep_cap)
    # rep_cap = min(n_loc*(md+1), S_loc_pad) bounds the distinct cells one
    # home can receive, so this stays 0; psum'd into dropped as a tripwire
    overflow = jnp.sum(remote & ~sendable)
    rep_entries = jnp.sum(l_vid >= 0)
    rep_bytes = rep_entries * entry_nbytes(l_vid, l_dst, l_cnt)

    own_start = shard_id * cells            # own home's block, wire-free
    o_vid = jax.lax.dynamic_slice(f_vid, (own_start,), (cells,))
    o_dst = jax.lax.dynamic_slice(f_dst, (own_start,), (cells,))
    o_cnt = jax.lax.dynamic_slice(f_cnt, (own_start,), (cells,))

    # ---- home: segmented outcome intervals, keyed v*C + start-rank ----
    e_vid = jnp.concatenate([o_vid, r_vid])
    e_dst = jnp.concatenate([o_dst, r_dst])
    e_cnt = jnp.concatenate([o_cnt, jnp.where(r_vid >= 0, r_cnt, 0)])
    evid = jnp.where((e_cnt > 0) & (e_vid >= 0), e_vid, n_pad)
    order = jnp.argsort(evid, stable=True)
    evid_s, cnt_s, dst_s = evid[order], e_cnt[order], e_dst[order]
    s = jnp.cumsum(cnt_s) - cnt_s           # exclusive cumsum (nonneg cnt)
    idx = jnp.arange(evid_s.shape[0])
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                evid_s[1:] != evid_s[:-1]])
    base = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, s, 0))
    sw = (s - base).astype(jnp.int32)       # rank interval start within v
    keys_s = jnp.where(evid_s < n_pad, evid_s * C + sw, _INT32_MAX)

    # ---- assign: uniform-random permutation of coupons within vertex ----
    u = jax.random.uniform(k_perm, (S_loc_pad,))
    gkey = jnp.where(elig, pos, n_pad)
    ord2 = jnp.lexsort((u, gkey))           # by vertex, random within
    gs = gkey[ord2]
    idx2 = jnp.arange(S_loc_pad)
    is_st2 = jnp.concatenate([jnp.ones((1,), bool), gs[1:] != gs[:-1]])
    rst = jax.lax.associative_scan(jnp.maximum,
                                   jnp.where(is_st2, idx2, 0))
    rank = jnp.zeros((S_loc_pad,), jnp.int32).at[ord2].set(
        (idx2 - rst).astype(jnp.int32))
    q = jnp.where(elig, pos * C + rank, 0)
    loc = jnp.clip(jnp.searchsorted(keys_s, q, side="right") - 1, 0,
                   keys_s.shape[0] - 1)
    out = dst_s[loc]                        # -2 = reset, >=0 = destination
    survive = elig & (out >= 0)
    new_pos = jnp.where(survive, out, pos)  # dead coupons keep final vertex
    new_alive = survive.astype(jnp.int32)
    traj = jax.lax.dynamic_update_slice(
        traj, jnp.where(survive, out, -1).astype(jnp.int32)[:, None],
        (jnp.int32(0), t))

    pending = jax.lax.psum(jnp.sum(survive), AXIS)
    overflow = jax.lax.psum(overflow, AXIS)
    rep_entries = jax.lax.psum(rep_entries, AXIS)
    rep_bytes = jax.lax.psum(rep_bytes, AXIS)
    return (new_pos[None], new_alive[None], traj[None],
            pending, overflow, rep_entries, rep_bytes)


# The step makers are memoized: a fresh jitted closure per engine call
# would recompile every stage program on every invocation (seconds per
# program on CPU), while equal (mesh, static-config) arguments produce
# byte-identical programs. jax interns Mesh objects, so repeat calls over
# the same devices hit the cache even when the caller rebuilds the mesh.
@lru_cache(maxsize=64)
def _make_p1_steps(mesh: Mesh, *, eps: float, n_loc: int, shards: int,
                   md: int, rep_cap: int, S_loc_pad: int,
                   layout, use_pallas: bool,
                   count_bound: Optional[int] = None):
    """Returns (request, sample, assign): the three jitted programs of one
    Phase-1 round. Split so the driver can time the sampler alone.
    `count_bound` is the declared upper bound on any routed count (the
    coupon-pool total) — forwarded to the count reductions so the f32
    segment kernel is bypassed when it could truncate (> 2^24)."""
    req_sh = shard_map(
        partial(_p1_request, n_loc=n_loc, shards=shards,
                use_pallas=use_pallas, count_bound=count_bound),
        mesh, in_specs=(P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(), P()))
    samp_sh = shard_map(
        partial(_p1_sample, eps=eps, n_loc=n_loc, shards=shards, md=md,
                layout=layout, use_pallas=use_pallas),
        mesh, in_specs=(P(AXIS),) * 4,
        out_specs=(P(AXIS),) * 3 + (P(),) * 2)
    asn_sh = shard_map(
        partial(_p1_assign, n_loc=n_loc, shards=shards, md=md,
                rep_cap=rep_cap, S_loc_pad=S_loc_pad),
        mesh, in_specs=(P(AXIS),) * 7 + (P(),),
        out_specs=(P(AXIS),) * 3 + (P(),) * 4)

    return jax.jit(req_sh), jax.jit(samp_sh), jax.jit(asn_sh)


# ---------------------------------------------------------------------------
# Phase 2: count-aggregated coupon stitching
# ---------------------------------------------------------------------------

def _p2_local(walks, next_c, used, tail_cnt, dest, cterm, psize, pstart,
              slot_v, *, n_loc: int, shards: int, S_loc_pad: int,
              use_pallas: bool, count_bound: Optional[int] = None):
    """One stitch superstep. Long walks are anonymous, so the state is a
    per-owned-vertex count: allocate the next unused coupons of each
    vertex's pool to the walks waiting there (natural-order consumption —
    distributionally identical to uniform-without-replacement because
    coupons are iid), retire walks whose coupon recorded an eps-reset,
    route the movers as per-destination counts, and bank pool-exhausted
    walks in `tail_cnt` for the naive fallback."""
    (walks, next_c, used, tail_cnt, dest, cterm, psize, pstart, slot_v) = (
        walks[0], next_c[0], used[0], tail_cnt[0], dest[0], cterm[0],
        psize[0], pstart[0], slot_v[0])
    shard_id = jax.lax.axis_index(AXIS)
    n_pad = shards * n_loc

    a = jnp.minimum(walks, psize - next_c)        # coupons allocatable now
    exh = walks - a                               # pool empty: naive tail
    off = jnp.arange(S_loc_pad, dtype=jnp.int32) - pstart[slot_v]
    nc = next_c[slot_v]
    alloc = (off >= nc) & (off < nc + a[slot_v])  # this round's used slots
    used = jnp.maximum(used, alloc.astype(jnp.int32))
    next_c = next_c + a
    term_now = alloc & (cterm > 0)      # coupon's eps-reset fired: walk done
    go = alloc & (cterm == 0)           # walk continues at coupon's dest
    dcnt = vertex_histogram(dest, go, n_pad, use_pallas=use_pallas)
    arrivals, sent_entries, sent_bytes = route_counts(
        dcnt, axis=AXIS, shard_id=shard_id, n_loc=n_loc, shards=shards,
        use_pallas=use_pallas, count_bound=count_bound)
    tail_cnt = tail_cnt + exh

    stitched = jax.lax.psum(jnp.sum(a), AXIS)
    terminated = jax.lax.psum(jnp.sum(term_now), AXIS)
    exhausted = jax.lax.psum(jnp.sum(exh), AXIS)
    active = jax.lax.psum(jnp.sum(arrivals), AXIS)
    entries = jax.lax.psum(sent_entries, AXIS)
    nbytes = jax.lax.psum(sent_bytes, AXIS)
    return (arrivals[None], next_c[None], used[None], tail_cnt[None],
            active, stitched, terminated, exhausted, entries, nbytes)


@lru_cache(maxsize=64)
def _make_p2_step(mesh: Mesh, *, n_loc: int, shards: int, S_loc_pad: int,
                  use_pallas: bool, count_bound: Optional[int] = None):
    fn = partial(_p2_local, n_loc=n_loc, shards=shards,
                 S_loc_pad=S_loc_pad, use_pallas=use_pallas,
                 count_bound=count_bound)
    sharded = shard_map(fn, mesh,
                        in_specs=(P(AXIS),) * 9,
                        out_specs=(P(AXIS),) * 4 + (P(),) * 6)

    @jax.jit
    def step(walks, next_c, used, tail_cnt, dest, cterm, psize, pstart,
             slot_v):
        return sharded(walks, next_c, used, tail_cnt, dest, cterm, psize,
                       pstart, slot_v)

    return step


# ---------------------------------------------------------------------------
# Phase 3: one aggregated counting round over the trajectory table
# ---------------------------------------------------------------------------

def _p3_local(traj, used, zeta, *, n_loc: int, shards: int,
              use_pallas: bool, count_bound: Optional[int] = None):
    """Histogram the used coupons' recorded moves and deliver the counts
    to the owner shards in ONE `route_counts` exchange."""
    traj, used, zeta = traj[0], used[0], zeta[0]
    shard_id = jax.lax.axis_index(AXIS)
    n_pad = shards * n_loc
    ids = jnp.where(used[:, None] > 0, traj, -1).reshape(-1)
    part = vertex_histogram(ids, ids >= 0, n_pad, use_pallas=use_pallas)
    arrivals, sent_entries, sent_bytes = route_counts(
        part, axis=AXIS, shard_id=shard_id, n_loc=n_loc, shards=shards,
        use_pallas=use_pallas, count_bound=count_bound)
    zeta = zeta + arrivals
    entries = jax.lax.psum(sent_entries, AXIS)
    nbytes = jax.lax.psum(sent_bytes, AXIS)
    return zeta[None], entries, nbytes


@lru_cache(maxsize=64)
def _make_p3_step(mesh: Mesh, *, n_loc: int, shards: int,
                  use_pallas: bool, count_bound: Optional[int] = None):
    fn = partial(_p3_local, n_loc=n_loc, shards=shards,
                 use_pallas=use_pallas, count_bound=count_bound)
    sharded = shard_map(fn, mesh, in_specs=(P(AXIS),) * 3,
                        out_specs=(P(AXIS), P(), P()))

    @jax.jit
    def step(traj, used, zeta):
        return sharded(traj, used, zeta)

    return step


# ---------------------------------------------------------------------------
# main driver
# ---------------------------------------------------------------------------

def _lane_cap(requested: Optional[int], load: int, shards: int,
              floor: int = 64) -> int:
    """Single home of the documented lane sizing rule `route_cap >= W/P`.

    With W items resident and P shards, ceil(W/P) slots per (src, dst)
    lane guarantee a full buffer can drain in P rounds even when every
    item targets one shard; floor division under-sizes the lane whenever
    W % P != 0. Defaults are computed with ceil division and the rule is
    asserted for explicit overrides too (an undersized lane only costs
    waiting latency, but it breaks the documented sizing contract)."""
    need = -(-max(int(load), 0) // shards)          # ceil(W / P)
    cap = max(need, floor) if requested is None else int(requested)
    assert cap >= need, (
        f"lane cap {cap} violates route_cap >= ceil(W/P) = {need} "
        f"(W={load}, P={shards})")
    return cap


@dataclasses.dataclass(frozen=True)
class ThreePhasePlan:
    """Every static size the 3-phase driver derives from (graph, shards,
    pool, K) — extracted so the CONGEST auditor can rebuild the EXACT
    step programs (the step makers are lru_cache-memoized on these values,
    so matching statics means the auditor traces the very objects the
    engine runs, not lookalikes)."""
    sg: object                 # distributed.ShardedGraph
    n_loc: int
    md: int
    S_loc_pad: int
    S_total: int
    rep_cap: int               # phase-1 reply lanes per shard pair
    route_cap2: int            # naive-tail walk lanes per shard pair
    cap2: int                  # naive-tail walk buffer per shard
    pool_pad: np.ndarray
    psize_sh: np.ndarray
    pstart_sh: np.ndarray
    layout: object             # aggregate_sampler.BucketLayout
    bperm_np: np.ndarray


def plan_three_phase(graph: CSRGraph, shards: int, pool_np: np.ndarray,
                     K: int, *, route_cap2: Optional[int] = None,
                     cap2: Optional[int] = None,
                     bucketed: bool = True) -> ThreePhasePlan:
    """Single home of the 3-phase static sizing rules (see ThreePhasePlan)."""
    n = graph.n
    sg = shard_graph(graph, shards)
    n_loc = sg.n_loc
    md = max(int(np.asarray(sg.out_deg).max()), 1)

    # coupon pool layout: contiguous per shard, padded to S_loc_pad
    pool_pad = np.zeros(sg.n_pad, dtype=np.int64)
    pool_pad[:n] = pool_np
    psize_sh = pool_pad.reshape(shards, n_loc)
    pstart_sh = np.zeros_like(psize_sh)
    pstart_sh[:, 1:] = np.cumsum(psize_sh, axis=1)[:, :-1]
    S_loc = psize_sh.sum(axis=1)
    S_loc_pad = max(int(S_loc.max()), 1)
    S_total = int(pool_np.sum())
    if shards * S_loc_pad >= 2 ** 31:
        raise ValueError("coupon pool too large for int32 ids")
    if (shards * n_loc + 1) * (S_loc_pad + 1) >= 2 ** 31:
        raise ValueError("vertex*rank outcome keys overflow int32")

    # Phase-1 reply lanes: a home can receive at most one cell per
    # (owned-vertex, outcome-class) pair and at most one per coupon
    rep_cap = min(n_loc * (md + 1), S_loc_pad)
    # tail (naive fallback) keeps the Algorithm-1 CONGEST sizing rule
    route_cap2 = _lane_cap(route_cap2, n * K, shards)
    if cap2 is None:
        cap2 = max(2 * n * K // shards, n_loc * K) + shards * 64

    deg_np = np.ascontiguousarray(
        np.asarray(sg.out_deg, np.int32).reshape(shards, n_loc))
    layout, bperm_np = build_layout_sharded(deg_np, md, bucketed=bucketed)
    return ThreePhasePlan(sg=sg, n_loc=n_loc, md=md, S_loc_pad=S_loc_pad,
                          S_total=S_total, rep_cap=rep_cap,
                          route_cap2=int(route_cap2), cap2=int(cap2),
                          pool_pad=pool_pad, psize_sh=psize_sh,
                          pstart_sh=pstart_sh, layout=layout,
                          bperm_np=bperm_np)


def _three_phase_layouts(n: int, pool_np: np.ndarray, cap2: int):
    """Elastic layout schema per stage — shared by the phase-machine and
    the CONGEST auditor's schema lint. Declared per stage so snapshots are
    mesh-size-agnostic: a resume onto a different device count re-homes
    every buffer through `checkpoint.relayout_staged_flat` (coupon slots
    re-placed via the pool bijection, vertex shards re-split, walk lanes
    re-bucketed, per-shard keys re-derived). Slot/vertex/walk/replicated
    buffers re-layout bit-exactly; per-shard `key` streams are re-derived,
    so a mid-phase-1 (or mid-tail, with tail walks live) elastic resume is
    statistically — not bit — identical."""
    _slot = partial(LayoutSpec, kind="slot", n=n, pool=pool_np)
    _vert = LayoutSpec(kind="vertex", n=n)
    _rep = LayoutSpec(kind="replicated")
    return dict(
        phase1=dict(pos=_slot(fill=-1), alive=_slot(fill=0),
                    traj=_slot(fill=-1), key=LayoutSpec(kind="key")),
        phase2=dict(walks=_vert, next_c=_vert, used=_slot(fill=0),
                    tail_cnt=_vert, dest=_slot(fill=-1),
                    cterm=_slot(fill=1), traj=_slot(fill=-1), zeta=_vert),
        phase3=dict(traj=_slot(fill=-1), used=_slot(fill=0), zeta=_vert,
                    tail_cnt=_vert),
        tail=dict(pos=LayoutSpec(kind="walk", n=n, cap=cap2, fill=-1),
                  zeta=_vert, key=LayoutSpec(kind="key"),
                  round=_rep, dropped=_rep, waited=_rep),
    )


@dataclasses.dataclass
class ImprovedDistResult:
    zeta: jnp.ndarray            # [n] global visit counts
    pi: jnp.ndarray
    shards: int
    walks_per_node: int
    eps: float
    lam: int
    eta: int
    ell: int
    rounds: int                  # total supersteps across all phases
    phase1_rounds: int
    report_rounds: int           # 0: the report phase is gone — coupons
                                 # stay home, so (dest, term) is local
    phase2_rounds: int           # stitch supersteps
    phase3_rounds: int           # aggregated counting exchanges (== 1)
    tail_rounds: int             # naive-fallback supersteps
    stitch_iterations: int
    exhausted_walks: int
    terminated_by_coupon: int
    tail_walks: int
    coupons_created: int
    coupons_used: int
    dropped: int
    waited: int
    a2a_bytes_total: int
    a2a_bytes_by_phase: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    a2a_entries_by_site: Dict[str, int] = dataclasses.field(
        default_factory=dict)   # routed lane entries per exchange site
                                # (phase1_req/phase1_rep/phase2/phase3/tail)
    phase2_records: List[dict] = dataclasses.field(default_factory=list)
    report: Optional[CongestReport] = None
    total_visits: int = 0
    restarts: int = 0            # supervisor recoveries (fault injection)
    checkpoints_written: int = 0
    sampler_us: float = 0.0      # total wall time in the Phase-1 sampler
    p1_occupancy: tuple = ()     # per-bucket rows-with-coupons, summed over
                                 # rounds and shards (len = #buckets)
    residual: int = 0            # sampler conservation leak — must stay 0


def distributed_improved_pagerank(
    graph: CSRGraph,
    eps: float,
    walks_per_node: Optional[int] = None,
    key: Optional[jnp.ndarray] = None,
    *,
    mesh: Optional[Mesh] = None,
    lam: Optional[int] = None,
    eta: Optional[int] = None,
    eta_safety: float = 2.0,
    cap2: Optional[int] = None,
    route_cap2: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
    bucketed: bool = True,
) -> ImprovedDistResult:
    """Run Algorithm 2 across all devices of `mesh` (default: all devices).

    `cap2`/`route_cap2` size only the naive-tail buffers (Phases 1-3 are
    count-aggregated and size themselves). With `checkpoint_dir` and/or
    `fail_at` set, the phase-machine runs under the checkpoint-restart
    supervisor (see `_run_three_phase`). `bucketed=False` keeps the
    single-bucket max_deg-wide Phase-1 sampler layout (the pre-bucketing
    shape, for benchmarking); the draws are layout-independent."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    key = key if key is not None else jax.random.PRNGKey(0)
    n = graph.n
    K = walks_per_node or walks_per_node_for(n, eps)
    log_n = math.log(max(n, 2))
    if lam is None:
        lam = max(1, int(math.ceil(math.sqrt(log_n))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))
    eta, pool_np = coupon_pool_sizes(graph, eps, K, lam, eta=eta,
                                     eta_safety=eta_safety)
    return _run_three_phase(
        graph, eps, K, key, mesh, pool_np=pool_np, eta=int(eta),
        lam=int(lam), ell=int(ell), cap2=cap2, route_cap2=route_cap2,
        max_rounds=max_rounds, bandwidth_bits=bandwidth_bits,
        use_pallas=use_pallas, checkpoint_dir=checkpoint_dir,
        fail_at=fail_at, checkpoint_every=checkpoint_every,
        max_restarts=max_restarts, resume=resume, bucketed=bucketed)


def _run_three_phase(
    graph: CSRGraph,
    eps: float,
    K: int,
    key: jnp.ndarray,
    mesh: Mesh,
    *,
    pool_np: np.ndarray,
    eta: int,
    lam: int,
    ell: int,
    cap2: Optional[int] = None,
    route_cap2: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
    bucketed: bool = True,
    result_cls: type = ImprovedDistResult,
    **extra_fields,
):
    """Budget-policy-agnostic 3-phase stitching driver, structured as a
    checkpointable phase-machine.

    The whole engine — Phase-1 count-aggregated short walks, Phase-2
    count-aggregated stitching, the Phase-3 one-shot counting exchange,
    the naive tail, and the host-float64 estimator — only ever sees the
    per-node pool-size vector `pool_np`, never the policy that produced
    it. `distributed_improved_pagerank` (Lemma 2, d(v)*eta) and
    `distributed_directed.distributed_directed_pagerank` (Section 5,
    uniform budgets in the LOCAL model) are thin frontends over this core.
    `result_cls`/`extra_fields` let a frontend return a telemetry subclass
    of ImprovedDistResult.

    Each phase is a `runtime.Stage` over a `StagedState` whose `arrays`
    hold the phase's device buffers and whose `host` dict holds the
    accumulators (round counters, wire volumes, traces, Phase-2 records).
    Without `checkpoint_dir`/`fail_at` the composed `StageSchedule` is
    stepped in a plain loop (no snapshot overhead); with either set, the
    `runtime.Supervisor` drives it with periodic stage-tagged checkpoints
    and (optionally) injected failures at the listed *global* rounds —
    round indices span all phases, so failures can land at phase
    boundaries or mid-phase. Recovery restores the latest snapshot and
    replays the identical trajectory: `zeta`/`pi` and all telemetry are
    bit-identical to an unfailed run. `resume=True` cold-starts from the
    latest snapshot in `checkpoint_dir` (a previously killed run).

    Elastic resume: every stage declares a `checkpoint.LayoutSpec` schema
    for its buffers, so the snapshot is mesh-size-agnostic — `resume=True`
    with a `mesh` of a DIFFERENT device count re-homes coupon slots,
    vertex shards, and walk lanes onto the new mesh and continues.
    Phases 2/3 are RNG-free, so a mid-Phase-2 resume re-layouts
    bit-exactly; only live per-shard key streams (mid-Phase-1, or a tail
    with surviving walks) are re-derived and therefore statistical,
    gated by the conformance tolerance.
    """
    shards = int(mesh.devices.size)
    n = graph.n
    use_pallas = resolve_use_pallas(use_pallas)

    # all static sizing comes from the shared plan (also what the CONGEST
    # auditor rebuilds — see ThreePhasePlan)
    plan = plan_three_phase(graph, shards, pool_np, K,
                            route_cap2=route_cap2, cap2=cap2,
                            bucketed=bucketed)
    sg, n_loc, md = plan.sg, plan.n_loc, plan.md
    S_loc_pad, S_total = plan.S_loc_pad, plan.S_total
    rep_cap, route_cap2, cap2 = plan.rep_cap, plan.route_cap2, plan.cap2
    pool_pad, psize_sh, pstart_sh = (plan.pool_pad, plan.psize_sh,
                                     plan.pstart_sh)
    spec = NamedSharding(mesh, P(AXIS))
    sg_rp = jax.device_put(sg.row_ptr, spec)
    sg_ci = jax.device_put(sg.col_idx, spec)
    sg_dg = jax.device_put(sg.out_deg, spec)

    # ---- Phase-1 placement: slot s of shard p = p's s-th coupon, at its
    # source vertex; slots beyond S_loc[p] are padding (never allocated) --
    pos0 = np.full((shards, S_loc_pad), -1, dtype=np.int32)
    slot_v_np = np.zeros((shards, S_loc_pad), dtype=np.int32)
    for p in range(shards):
        owned = pool_pad[p * n_loc:(p + 1) * n_loc]
        src = np.repeat(np.arange(p * n_loc, (p + 1) * n_loc,
                                  dtype=np.int32), owned)
        pos0[p, : len(src)] = src
        slot_v_np[p, : len(src)] = src - p * n_loc
    # ---- Phase-2 placement: K long walks per real vertex (counts) ----
    walks0_np = np.zeros((shards, n_loc), dtype=np.int32)
    zeta3_np = np.zeros((shards, n_loc), np.int32)
    for p in range(shards):
        lo = min(p * n_loc, n)
        hi = min((p + 1) * n_loc, n)
        walks0_np[p, : hi - lo] = K
        zeta3_np[p, : hi - lo] = K           # start visits of long walks

    key, k1, k_tail = jax.random.split(key, 3)
    k1_shards = jax.random.split(k1, shards)

    # ---- Phase-1 degree-bucketed sampler layout (static, memoized) ----
    layout = plan.layout
    bperm_j = jax.device_put(jnp.asarray(plan.bperm_np), spec)

    # ---- jitted per-phase step functions (shared by fresh + resumed) ----
    p1_req, p1_samp, p1_asn = _make_p1_steps(
        mesh, eps=float(eps), n_loc=n_loc, shards=shards, md=md,
        rep_cap=rep_cap, S_loc_pad=S_loc_pad, layout=layout,
        use_pallas=use_pallas, count_bound=S_total)
    p2_step = _make_p2_step(mesh, n_loc=n_loc, shards=shards,
                            S_loc_pad=S_loc_pad, use_pallas=use_pallas,
                            count_bound=n * K)
    p3_step = _make_p3_step(mesh, n_loc=n_loc, shards=shards,
                            use_pallas=use_pallas, count_bound=S_total)
    tail_step = _make_superstep(mesh, float(eps), n_loc, shards,
                                int(route_cap2), 0, use_pallas=use_pallas)
    psize_j = jax.device_put(jnp.asarray(psize_sh, dtype=jnp.int32), spec)
    pstart_j = jax.device_put(jnp.asarray(pstart_sh, dtype=jnp.int32), spec)
    slot_v_j = jax.device_put(jnp.asarray(slot_v_np), spec)

    # ---------------- stage step functions + host transitions ----------
    # Telemetry lives in the JSON-able `host` dict so a restored snapshot
    # rolls the accumulators back in lockstep with the device buffers.

    def _phase1(ms: StagedState):
        a = ms.arrays
        t = jnp.int32(ms.host["phase1_rounds"])
        c, req_entries, req_bytes = p1_req(a["pos"], a["alive"])
        t0 = time.perf_counter()
        f_cnt, key1, k_perm, occ, residual = p1_samp(
            bperm_j, sg_dg, c, a["key"])
        jax.block_until_ready(f_cnt)
        t1 = time.perf_counter()
        pos, alive, traj, pending, overflow, rep_entries, rep_bytes = \
            p1_asn(sg_rp, sg_ci, a["pos"], a["alive"], a["traj"],
                   f_cnt, k_perm, t)
        a.update(pos=pos, alive=alive, traj=traj, key=key1)
        # one device sync for all the round's telemetry, not one per value
        (pending, overflow, req_e, req_b, rep_e, rep_b, occ_v,
         res) = jax.device_get((pending, overflow, req_entries, req_bytes,
                                rep_entries, rep_bytes, occ, residual))
        h = ms.host
        h["phase1_rounds"] += 1
        h["dropped"] += int(overflow)
        h["wire"]["phase1"] += int(req_b) + int(rep_b)
        h["wire_entries"]["phase1_req"] += int(req_e)
        h["wire_entries"]["phase1_rep"] += int(rep_e)
        h["sampler_us"] += (t1 - t0) * 1e6
        h["p1_occupancy"] = [int(x) + int(y)
                             for x, y in zip(h["p1_occupancy"], occ_v)]
        h["residual"] += int(res)
        h["traces"].append([int(pending), int(req_e) + int(rep_e)])
        # each coupon gets exactly lam step opportunities, one per round
        return ms, int(pending) == 0 or h["phase1_rounds"] >= lam

    def _after_phase1(ms: StagedState) -> StagedState:
        # Coupons never moved buffers, so their summaries are already
        # home-local: dest = final vertex, cterm = the reset fired.
        # The trajectory table rides along untouched for Phase 3.
        a = ms.arrays
        ms.arrays = dict(
            walks=jax.device_put(jnp.asarray(walks0_np), spec),
            next_c=jax.device_put(jnp.zeros((shards, n_loc), jnp.int32),
                                  spec),
            used=jax.device_put(jnp.zeros((shards, S_loc_pad), jnp.int32),
                                spec),
            tail_cnt=jax.device_put(jnp.zeros((shards, n_loc), jnp.int32),
                                    spec),
            dest=a["pos"], cterm=1 - a["alive"], traj=a["traj"],
            zeta=jax.device_put(jnp.asarray(zeta3_np), spec))
        return ms

    def _phase2(ms: StagedState):
        a = ms.arrays
        (walks, next_c, used, tail_cnt, active, stitched, terminated,
         exhausted, entries, nbytes) = p2_step(
            a["walks"], a["next_c"], a["used"], a["tail_cnt"], a["dest"],
            a["cterm"], psize_j, pstart_j, slot_v_j)
        a.update(walks=walks, next_c=next_c, used=used, tail_cnt=tail_cnt)
        # one device sync for all six telemetry scalars, not six
        active, stitched, terminated, exhausted, entries, nbytes = (
            int(x) for x in jax.device_get(
                (active, stitched, terminated, exhausted, entries, nbytes)))
        h = ms.host
        h["phase2_rounds"] += 1
        h["stitches"] += stitched
        h["terminated"] += terminated
        h["exhausted"] += exhausted
        h["wire"]["phase2"] += nbytes
        h["wire_entries"]["phase2"] += entries
        h["phase2_records"].append(dict(
            active=active, stitched=stitched,
            terminated=terminated, exhausted=exhausted))
        h["traces"].append([active, entries])
        if active == 0:
            return ms, True
        if h["phase2_rounds"] >= max_rounds:
            raise RuntimeError("phase 2 did not converge within max_rounds")
        return ms, False

    def _after_phase2(ms: StagedState) -> StagedState:
        a = ms.arrays
        ms.host["coupons_used"] = int(np.asarray(a["used"]).sum())
        ms.arrays = dict(traj=a["traj"], used=a["used"], zeta=a["zeta"],
                         tail_cnt=a["tail_cnt"])
        return ms

    def _phase3(ms: StagedState):
        a = ms.arrays
        zeta, entries, nbytes = p3_step(a["traj"], a["used"], a["zeta"])
        a["zeta"] = zeta
        entries, nbytes = (int(x) for x in
                           jax.device_get((entries, nbytes)))
        h = ms.host
        h["phase3_rounds"] += 1
        h["wire"]["phase3"] += nbytes
        h["wire_entries"]["phase3"] += entries
        h["traces"].append([0, entries])
        return ms, True          # the whole count lands in ONE exchange

    def _after_phase3(ms: StagedState) -> StagedState:
        a = ms.arrays
        h = ms.host
        tail_np = np.asarray(a["tail_cnt"])
        pos_tail = np.full((shards, cap2), -1, dtype=np.int32)
        for p in range(shards):
            vids = np.repeat(
                np.arange(p * n_loc, (p + 1) * n_loc, dtype=np.int32),
                tail_np[p])
            assert len(vids) <= cap2, "cap2 too small for tail placement"
            pos_tail[p, : len(vids)] = vids
        h["tail_walks"] = int(tail_np.sum())
        h["tail_active"] = h["tail_walks"]
        ms.arrays = dict(
            pos=jax.device_put(jnp.asarray(pos_tail), spec),
            zeta=a["zeta"],
            key=jax.device_put(jax.random.split(k_tail, shards), spec),
            round=jnp.int32(0), dropped=jnp.int32(0), waited=jnp.int32(0))
        return ms

    def _tail(ms: StagedState):
        a = ms.arrays
        h = ms.host
        if h["tail_active"]:
            if h["tail_rounds"] >= max_rounds:
                raise RuntimeError(
                    "tail walks did not converge in max_rounds")
            tstate = DistState(pos=a["pos"], zeta=a["zeta"], key=a["key"],
                               round=a["round"], dropped=a["dropped"],
                               waited=a["waited"])
            tstate, active, entries, a2a = tail_step(sg_rp, sg_ci, sg_dg,
                                                     tstate)
            a.update(pos=tstate.pos, zeta=tstate.zeta, key=tstate.key,
                     round=tstate.round, dropped=tstate.dropped,
                     waited=tstate.waited)
            active, entries, a2a = (int(x) for x in
                                    jax.device_get((active, entries, a2a)))
            h["tail_rounds"] += 1
            h["wire"]["tail"] += a2a
            h["wire_entries"]["tail"] += entries
            h["traces"].append([active, entries])
            h["tail_active"] = active
        if h["tail_active"]:
            return ms, False
        h["dropped"] += int(a["dropped"])
        h["waited"] += int(a["waited"])
        return ms, True

    schedule = StageSchedule([
        Stage("phase1", _phase1, on_done=_after_phase1),
        Stage("phase2", _phase2, on_done=_after_phase2),
        Stage("phase3", _phase3, on_done=_after_phase3),
        Stage("tail", _tail),
    ])

    traj0 = np.full((shards, S_loc_pad, lam), -1, dtype=np.int32)
    # ---- layout schema: how each stage's buffers sit on the mesh ------
    # (shared with the CONGEST auditor — see _three_phase_layouts)
    layouts = _three_phase_layouts(n, pool_np, cap2)
    ms = StagedState(
        stage=schedule.first_stage,
        arrays=dict(
            pos=jax.device_put(jnp.asarray(pos0), spec),
            alive=jax.device_put(jnp.asarray((pos0 >= 0).astype(np.int32)),
                                 spec),
            traj=jax.device_put(jnp.asarray(traj0), spec),
            key=jax.device_put(k1_shards, spec)),
        host=dict(phase1_rounds=0, report_rounds=0, phase2_rounds=0,
                  phase3_rounds=0, tail_rounds=0, dropped=0, waited=0,
                  stitches=0, terminated=0, exhausted=0, coupons_used=0,
                  tail_walks=0, tail_active=0,
                  wire=dict(phase1=0, report=0, phase2=0, phase3=0, tail=0),
                  wire_entries=dict(phase1_req=0, phase1_rep=0, phase2=0,
                                    phase3=0, tail=0),
                  sampler_us=0.0, p1_occupancy=[0] * len(layout.caps),
                  residual=0,
                  traces=[], phase2_records=[]),
        layouts=layouts, shards=shards)

    # ---------------- drive: plain loop or checkpointing supervisor ----
    _scalar_keys = ("round", "dropped", "waited")

    def _put(name: str, arr: np.ndarray):
        if name in _scalar_keys:
            return jnp.asarray(arr)              # replicated scalars
        return jax.device_put(jnp.asarray(arr), spec)

    # global rounds sum over the four stages, each bounded by max_rounds
    # (the per-stage guards raise on divergence)
    ms, restarts, checkpoints_written = run_staged(
        schedule, ms, _put, checkpoint_dir=checkpoint_dir, fail_at=fail_at,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts,
        resume=resume,
        max_rounds=len(schedule.stages) * max_rounds + len(schedule.stages),
        tmp_prefix="pr3p_ckpt_")

    # ---------------- estimator: host float64 scaling ------------------
    zeta = ms.arrays["zeta"].reshape(-1)[:n]
    pi = pagerank_from_visits(zeta, n, K, eps)
    total_visits = int(np.asarray(zeta, dtype=np.int64).sum())

    h = ms.host
    wire = h["wire"]
    rounds = (h["phase1_rounds"] + h["report_rounds"] + h["phase2_rounds"]
              + h["phase3_rounds"] + h["tail_rounds"])
    traces = [RoundTrace(active_walks=a, messages=m, max_edge_count=1,
                         total_count=m) for a, m in h["traces"]]
    report = CongestReport(traces=traces, n=n,
                           bandwidth_bits=bandwidth_bits
                           or default_bandwidth(n))
    return result_cls(
        zeta=zeta, pi=pi, shards=shards, walks_per_node=K, eps=eps,
        lam=int(lam), eta=int(eta), ell=int(ell), rounds=rounds,
        phase1_rounds=h["phase1_rounds"], report_rounds=h["report_rounds"],
        phase2_rounds=h["phase2_rounds"], phase3_rounds=h["phase3_rounds"],
        tail_rounds=h["tail_rounds"], stitch_iterations=h["phase2_rounds"],
        exhausted_walks=h["exhausted"],
        terminated_by_coupon=h["terminated"], tail_walks=h["tail_walks"],
        coupons_created=S_total, coupons_used=h["coupons_used"],
        dropped=h["dropped"], waited=h["waited"],
        a2a_bytes_total=sum(wire.values()), a2a_bytes_by_phase=wire,
        a2a_entries_by_site=dict(h["wire_entries"]),
        phase2_records=h["phase2_records"], report=report,
        total_visits=total_visits, restarts=restarts,
        checkpoints_written=checkpoints_written,
        sampler_us=float(h["sampler_us"]),
        p1_occupancy=tuple(h["p1_occupancy"]),
        residual=int(h["residual"]), **extra_fields)


# ---------------------------------------------------------------------------
# CONGEST auditor spec
# ---------------------------------------------------------------------------

def three_phase_audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float,
                           K: int, pool_np: np.ndarray, lam: int,
                           engine: str = "improved",
                           use_pallas: bool = False,
                           bucketed: bool = True):
    """CONGEST-auditor spec for the 3-phase engines (improved + directed
    frontends): all six stage programs rebuilt through the SAME memoized
    step makers with the SAME statics the engine would use (via
    `plan_three_phase`), each exchange's declared per-round wire budget,
    and the elastic layout schema.

    The tail stage is a walk-class exchange whose runtime lane cap scales
    with W/P; overflow there waits rather than widening the lane, so the
    auditor pins route_cap = cap = n_loc at trace time — any pinned cap
    yields a correct (and W-free) program to verify."""
    from repro.core.accounting import (EngineAuditSpec, ExchangeSite,
                                       StageProgram)
    shards = int(mesh.devices.size)
    n = graph.n
    plan = plan_three_phase(graph, shards, pool_np, K, bucketed=bucketed)
    n_loc, md = plan.n_loc, plan.md
    S_loc_pad, S_total = plan.S_loc_pad, plan.S_total
    rep_cap = plan.rep_cap

    p1_req, p1_samp, p1_asn = _make_p1_steps(
        mesh, eps=float(eps), n_loc=n_loc, shards=shards, md=md,
        rep_cap=rep_cap, S_loc_pad=S_loc_pad, layout=plan.layout,
        use_pallas=use_pallas, count_bound=S_total)
    p2_step = _make_p2_step(mesh, n_loc=n_loc, shards=shards,
                            S_loc_pad=S_loc_pad, use_pallas=use_pallas,
                            count_bound=n * K)
    p3_step = _make_p3_step(mesh, n_loc=n_loc, shards=shards,
                            use_pallas=use_pallas, count_bound=S_total)
    tail_cap = n_loc                       # auditor-pinned (walk-class)
    tail_step = _make_superstep(mesh, float(eps), n_loc, shards,
                                tail_cap, 0, use_pallas=use_pallas)

    sds = jax.ShapeDtypeStruct
    i32, u32 = jnp.int32, jnp.uint32
    sg = plan.sg
    rp = sds(sg.row_ptr.shape, sg.row_ptr.dtype)
    ci = sds(sg.col_idx.shape, sg.col_idx.dtype)
    dg = sds(sg.out_deg.shape, sg.out_deg.dtype)
    pos = sds((shards, S_loc_pad), i32)
    alive = sds((shards, S_loc_pad), i32)
    traj = sds((shards, S_loc_pad, int(lam)), i32)
    key = sds((shards, 2), u32)
    bperm = sds(plan.bperm_np.shape, plan.bperm_np.dtype)
    c = sds((shards, shards * n_loc), i32)
    f_cnt = sds((shards, shards * n_loc * (md + 1)), i32)
    t = sds((), i32)
    vert = sds((shards, n_loc), i32)
    slot = sds((shards, S_loc_pad), i32)
    tail_state = DistState(pos=sds((shards, tail_cap), i32), zeta=vert,
                           key=key, round=t, dropped=t, waited=t)

    count_budget = shards * n_loc          # Lemma-1 lanes: distinct vertices
    _count = dict(entry_nbytes=8, lane_entries=count_budget,
                  budget_entries=count_budget, wire_class="count",
                  budget_formula="P * n_loc distinct (vertex, count) pairs")
    rep_site = ExchangeSite(
        site="phase1_rep", entry_nbytes=12,
        lane_entries=shards * rep_cap,
        budget_entries=shards * n_loc * (md + 1),
        budget_formula=("P * min(n_loc*(max_deg+1), S_loc_pad) distinct "
                        "(vertex, class, count) cells <= P*n_loc*(md+1)"),
        wire_class="count",
        note="stacked F=3 lanes (vertex, outcome class, count)")
    tail_site = ExchangeSite(
        site="tail", entry_nbytes=4, lane_entries=shards * tail_cap,
        budget_entries=shards * n_loc,
        budget_formula="P * n_loc lane slots (auditor-pinned cap = n_loc)",
        wire_class="walk",
        note="naive-fallback walk routing; overflow waits, never widens")

    progs = [
        StageProgram(stage="phase1", program="request", fn=p1_req,
                     example_args=(pos, alive),
                     sites=(ExchangeSite(site="phase1_req", **_count),),
                     count_bound=S_total),
        StageProgram(stage="phase1", program="sample", fn=p1_samp,
                     example_args=(bperm, dg, c, key), sites=(),
                     count_bound=S_total),
        StageProgram(stage="phase1", program="assign", fn=p1_asn,
                     example_args=(rp, ci, pos, alive, traj, f_cnt, key, t),
                     sites=(rep_site,), count_bound=S_total),
        StageProgram(stage="phase2", program="stitch", fn=p2_step,
                     example_args=(vert, vert, slot, vert, slot, slot,
                                   vert, vert, slot),
                     sites=(ExchangeSite(site="phase2", **_count),),
                     count_bound=n * K),
        StageProgram(stage="phase3", program="count", fn=p3_step,
                     example_args=(traj, slot, vert),
                     sites=(ExchangeSite(site="phase3", **_count),),
                     count_bound=S_total),
        StageProgram(stage="tail", program="step", fn=tail_step,
                     example_args=(rp, ci, dg, tail_state),
                     sites=(tail_site,), count_bound=n * K),
    ]
    return EngineAuditSpec(
        engine=engine, programs=progs,
        stage_arrays={
            "phase1": ("pos", "alive", "traj", "key"),
            "phase2": ("walks", "next_c", "used", "tail_cnt", "dest",
                       "cterm", "traj", "zeta"),
            "phase3": ("traj", "used", "zeta", "tail_cnt"),
            "tail": ("pos", "zeta", "key", "round", "dropped", "waited"),
        },
        layouts=_three_phase_layouts(n, pool_np, plan.cap2),
        meta=dict(shards=shards, n=graph.n, K=K, lam=int(lam), md=md,
                  rep_cap=rep_cap, S_loc_pad=S_loc_pad, S_total=S_total))


def audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float = 0.2,
               walks_per_node: int = 2, use_pallas: bool = False,
               bucketed: bool = True):
    """Lemma-2 (degree-proportional pools) frontend of the 3-phase audit
    spec — mirrors `distributed_improved_pagerank`'s sizing exactly."""
    n = graph.n
    K = walks_per_node
    log_n = math.log(max(n, 2))
    lam = max(1, int(math.ceil(math.sqrt(log_n))))
    _, pool_np = coupon_pool_sizes(graph, eps, K, lam)
    return three_phase_audit_spec(graph, mesh, eps=eps, K=K,
                                  pool_np=pool_np, lam=lam,
                                  engine="improved", use_pallas=use_pallas,
                                  bucketed=bucketed)
