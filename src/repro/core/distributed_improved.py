"""Multi-device IMPROVED-PAGERANK engine — shard_map realization of
Algorithm 2 on the vertex-partitioned `ShardedGraph`.

The single-device `improved_pagerank.py` holds the whole coupon pool and
every trajectory in one address space; this engine is the CONGEST-faithful
TPU-pod version: vertices are partitioned into contiguous shards (one per
mesh device) and every exchange is a fixed-capacity `all_to_all` built from
the shared lane machinery in `routing.py`. Payloads carry anonymous
positions/counters, never walk identities (Lemma 1 discipline).

Phase 1 — short-walk pre-computation. Shard p owns the coupons of its
  vertices: vertex v gets pool_size(v) = d(v)*eta coupons (Lemma 2 sizing,
  see `improved_pagerank.coupon_pool_sizes`), each a PageRank walk given
  exactly lambda = ceil(sqrt(log n)) step opportunities (eps-reset or a
  dangling vertex terminates it early). Coupon ids are `home * S_loc_pad +
  local_index`, so a coupon's home shard is a single integer divide.
  Walks move with route/step supersteps identical to the Algorithm 1
  engine (`distributed.py`): cross-shard movers ride `route_cap`-bounded
  lanes and *wait* when a lane is full. A closing report exchange routes
  each coupon's (destination, length, terminated) summary back to its
  home shard — the paper's "destinations report their ID" step.

Phase 2 — stitching. The n*K long walks live at the owner shard of their
  current connector vertex. Each stitch superstep routes walks to their
  connector's owner, then allocates each walk the next unused coupon of
  that connector (sort-and-rank gives concurrent walks consecutive
  offsets — natural-order consumption, distributionally identical to
  uniform-without-replacement because coupons are iid). The walk jumps to
  the coupon's recorded destination in O(1) rounds and keeps stitching
  until a coupon's recorded eps-reset fires (a coupon is a fresh iid
  short walk, so unlimited stitching samples the same distribution as
  naive walking — no length cap needed for unbiasedness). A walk whose
  connector pool is exhausted (eta undersized — the paper's whp bound
  violated) falls back to naive distributed walking, tracked per round.

Phase 3 — counting. Used-coupon visits are counted at owner shards by
  *deterministic replay* of Phase 1 (same keys, same buffers, same lane
  schedule => identical trajectories), with arrivals masked by the used
  bitmap — the distributed analogue of the paper's reverse-trace; the
  replay costs exactly phase1_rounds supersteps and is charged to Phase 3.
  The used bitmap is broadcast once (its bytes are charged to Phase 3 wire
  volume). Fallback/tail walks then finish naively through the Algorithm 1
  superstep (`distributed._make_superstep`), counting arrivals into the
  same sharded zeta; the estimator pi = zeta * eps/(nK) is reduced with a
  final psum over the mesh axis.

Static shapes throughout; buffer overflow is counted in `dropped` and must
stay 0 for an exact run. Sizing rule, per phase with W resident walks:
`cap >= max(2*W/P, W_loc_max) + P*64` with `route_cap >= W/P` (mirrors
`distributed.py`; the `W_loc_max` term covers degree-skewed Phase 1
starts).

The phases only ever see a per-node pool-size vector, so the whole driver
lives in the budget-policy-agnostic `_run_three_phase`; this module's
public `distributed_improved_pagerank` feeds it Lemma-2 degree-proportional
pools, and `distributed_directed.distributed_directed_pagerank` feeds it
the Section-5 uniform/LOCAL pools.

Fault tolerance — the driver is a *checkpointable phase-machine*: each
phase (phase1, report, phase2, phase3, tail) is a named `runtime.Stage`
whose snapshot is the stage's device buffers (walk buffers, PRNG keys,
coupon tables, the `used` bitmap) plus the host accumulators (wire/trace
telemetry, round counters) as a pytree of arrays. With `checkpoint_dir`/
`fail_at` set, the `runtime.Supervisor` drives the composed
`StageSchedule`: a killed run resumes mid-phase from the latest
stage-tagged snapshot and — because every stage is deterministic given its
buffers and keys (Phase 3 *depends* on that determinism for replay) —
produces bit-identical `zeta`/`pi` and telemetry vs an unfailed run.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.accounting import CongestReport, RoundTrace, default_bandwidth
from repro.core.distributed import (AXIS, DistState, _make_superstep,
                                    shard_graph, shard_map)
from repro.core.graph import CSRGraph
from repro.core.improved_pagerank import coupon_pool_sizes
from repro.core.routing import (advance_owned, count_owned_arrivals,
                                exchange_stacked, lane_slots, merge_walks,
                                pack_lanes, rank_within, route_walks)
from repro.core.simple_pagerank import walks_per_node_for
from repro.runtime import Stage, StagedState, StageSchedule, run_staged


# ---------------------------------------------------------------------------
# Phase 1: short-walk pre-computation (+ deterministic replay for Phase 3)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShortWalkState:
    pos: jnp.ndarray     # [P, cap1] global vertex, -1 = empty slot
    cid: jnp.ndarray     # [P, cap1] coupon id = home * S_loc_pad + local idx
    steps: jnp.ndarray   # [P, cap1] step opportunities consumed (<= lam)
    moves: jnp.ndarray   # [P, cap1] actual moves (the coupon's length)
    alive: jnp.ndarray   # [P, cap1] 1 until the eps-reset / dangling stop
    key: jnp.ndarray     # [P, 2] per-shard PRNG keys
    zeta: jnp.ndarray    # [P, n_loc] visit counters (written only in replay)


def _p1_local(rp, ci, dg, pos, cid, steps, moves, alive, key, zeta, used, *,
              eps: float, lam: int, n_loc: int, shards: int, route_cap: int,
              count: bool):
    """One Phase-1 super-step on a single shard (route, then step).

    With `count=True` (the Phase-3 replay) arrivals of coupons flagged in
    the replicated `used` bitmap are added to zeta at the owner shard —
    immediately for intra-shard moves, at receive time for routed ones.
    """
    rp, ci, dg, pos, cid, steps, moves, alive, key, zeta = (
        rp[0], ci[0], dg[0], pos[0], cid[0], steps[0], moves[0], alive[0],
        key[0], zeta[0])
    shard_id = jax.lax.axis_index(AXIS)

    fields = dict(cid=cid, steps=steps, moves=moves, alive=alive)
    kept_pos, kept_f, recv_pos, recv_f, waited, sent = route_walks(
        pos, fields, axis=AXIS, shard_id=shard_id, n_loc=n_loc,
        shards=shards, route_cap=route_cap)
    arrived = recv_pos >= 0
    if count:
        u = used[jnp.clip(recv_f["cid"], 0, used.shape[0] - 1)] > 0
        zeta = zeta + count_owned_arrivals(arrived & u, recv_pos, shard_id,
                                           n_loc)
    pos, f, dropped = merge_walks(kept_pos, kept_f, recv_pos, recv_f,
                                  pos.shape[0])
    cid, steps, moves, alive = f["cid"], f["steps"], f["moves"], f["alive"]

    key, k_term, k_edge = jax.random.split(key, 3)
    valid = pos >= 0
    owned = valid & (pos // n_loc == shard_id)
    eligible = owned & (alive > 0) & (steps < lam)
    survive, dst = advance_owned(rp, ci, dg, pos, eligible, k_term, k_edge,
                                 eps, shard_id, n_loc)
    new_pos = jnp.where(survive, dst, pos)
    steps = steps + eligible.astype(jnp.int32)
    alive = jnp.where(eligible, survive.astype(jnp.int32), alive)
    moves = moves + survive.astype(jnp.int32)
    if count:
        u = used[jnp.clip(cid, 0, used.shape[0] - 1)] > 0
        local_arrival = survive & (dst // n_loc == shard_id)
        zeta = zeta + count_owned_arrivals(local_arrival & u, dst, shard_id,
                                           n_loc)

    # work left: walks with step opportunities remaining, plus in-flight
    # walks that still must be delivered to (and recorded at) their owner
    owned2 = (new_pos >= 0) & (new_pos // n_loc == shard_id)
    working = ((alive > 0) & (steps < lam)) | ((new_pos >= 0) & ~owned2)
    pending = jax.lax.psum(jnp.sum(working), AXIS)
    dropped = jax.lax.psum(dropped, AXIS)
    waited = jax.lax.psum(waited, AXIS)
    sent = jax.lax.psum(sent, AXIS)
    return (new_pos[None], cid[None], steps[None], moves[None], alive[None],
            key[None], zeta[None], pending, dropped, waited, sent)


def _make_p1_step(mesh: Mesh, *, eps: float, lam: int, n_loc: int,
                  shards: int, route_cap: int, count: bool):
    fn = partial(_p1_local, eps=eps, lam=lam, n_loc=n_loc, shards=shards,
                 route_cap=route_cap, count=count)
    sharded = shard_map(
        fn, mesh,
        in_specs=(P(AXIS),) * 10 + (P(),),
        out_specs=(P(AXIS),) * 7 + (P(), P(), P(), P()))

    @jax.jit
    def step(rp, ci, dg, st: ShortWalkState, used):
        (pos, cid, steps, moves, alive, key, zeta,
         pending, dropped, waited, sent) = sharded(
            rp, ci, dg, st.pos, st.cid, st.steps, st.moves, st.alive,
            st.key, st.zeta, used)
        return (ShortWalkState(pos=pos, cid=cid, steps=steps, moves=moves,
                               alive=alive, key=key, zeta=zeta),
                pending, dropped, waited, sent)

    return step


# ---------------------------------------------------------------------------
# Phase 1 closing report: coupon summaries back to their home shards
# ---------------------------------------------------------------------------

def _report_local(pos, cid, moves, alive, pending, dest, clen, cterm, *,
                  shards: int, S_loc_pad: int, rep_cap: int):
    """Route each finished coupon's (dest, length, terminated) summary to
    its home shard; up to rep_cap per target per round, the rest wait."""
    pos, cid, moves, alive, pending, dest, clen, cterm = (
        pos[0], cid[0], moves[0], alive[0], pending[0], dest[0], clen[0],
        cterm[0])
    shard_id = jax.lax.axis_index(AXIS)
    is_p = pending > 0
    home = jnp.where(is_p, cid // S_loc_pad, shards)
    term = 1 - alive

    local_rep = is_p & (home == shard_id)
    li = jnp.where(local_rep, cid % S_loc_pad, S_loc_pad)
    dest = dest.at[li].set(jnp.where(local_rep, pos, 0), mode="drop")
    clen = clen.at[li].set(jnp.where(local_rep, moves, 0), mode="drop")
    cterm = cterm.at[li].set(jnp.where(local_rep, term, 0), mode="drop")

    remote = is_p & (home != shard_id)
    sendable, flat_idx = lane_slots(home, remote, shards, rep_cap)
    l_cid = pack_lanes(flat_idx, cid, sendable, shards, rep_cap, fill=-1)
    r_cid, r_pos, r_mov, r_trm = exchange_stacked(
        [l_cid] + [pack_lanes(flat_idx, v, sendable, shards, rep_cap,
                              fill=0) for v in (pos, moves, term)],
        AXIS, shards, rep_cap)
    got = r_cid >= 0
    ri = jnp.where(got, r_cid % S_loc_pad, S_loc_pad)
    dest = dest.at[ri].set(jnp.where(got, r_pos, 0), mode="drop")
    clen = clen.at[ri].set(jnp.where(got, r_mov, 0), mode="drop")
    cterm = cterm.at[ri].set(jnp.where(got, r_trm, 0), mode="drop")

    new_pending = (is_p & ~local_rep & ~sendable).astype(jnp.int32)
    left = jax.lax.psum(jnp.sum(new_pending), AXIS)
    sent = jax.lax.psum(jnp.sum(l_cid >= 0), AXIS)
    return (new_pending[None], dest[None], clen[None], cterm[None],
            left, sent)


def _make_report_step(mesh: Mesh, *, shards: int, S_loc_pad: int,
                      rep_cap: int):
    fn = partial(_report_local, shards=shards, S_loc_pad=S_loc_pad,
                 rep_cap=rep_cap)
    sharded = shard_map(fn, mesh,
                        in_specs=(P(AXIS),) * 8,
                        out_specs=(P(AXIS),) * 4 + (P(), P()))

    @jax.jit
    def step(pos, cid, moves, alive, pending, dest, clen, cterm):
        return sharded(pos, cid, moves, alive, pending, dest, clen, cterm)

    return step


# ---------------------------------------------------------------------------
# Phase 2: coupon stitching with static connector exchanges
# ---------------------------------------------------------------------------

def _p2_local(pos, lend, mode, next_c, used, psize, pstart, dest, clen,
              cterm, *, n_loc: int, shards: int, route_cap: int,
              S_loc_pad: int):
    """One stitch super-step: route long walks to their connector's owner,
    then allocate each a distinct next-unused coupon and jump to its
    destination. `mode` 0 = stitching, 1 = fallback (naive tail).

    Unlike the single-device engine (which stops stitching at ell - lam
    and walks the tail naively), walks here stitch until their reset
    fires: a coupon is a fresh iid short walk from the connector, so
    unlimited stitching samples exactly the same distribution while
    keeping every round a O(1)-stitch round — the naive fallback is
    reserved for pool exhaustion. Expected coupons per walk is
    1/(1-(1-eps)^lam) < 1/(eps*lam) + 1, so `coupon_pool_sizes` still
    overprovisions."""
    pos, lend, mode, next_c, used, psize, pstart, dest, clen, cterm = (
        pos[0], lend[0], mode[0], next_c[0], used[0], psize[0], pstart[0],
        dest[0], clen[0], cterm[0])
    shard_id = jax.lax.axis_index(AXIS)

    kept_pos, kept_f, recv_pos, recv_f, waited, sent = route_walks(
        pos, dict(lend=lend, mode=mode), axis=AXIS, shard_id=shard_id,
        n_loc=n_loc, shards=shards, route_cap=route_cap)
    pos, f, dropped = merge_walks(kept_pos, kept_f, recv_pos, recv_f,
                                  pos.shape[0])
    lend, mode = f["lend"], f["mode"]

    # ---- allocate: distinct next-unused coupon per co-located walk ----
    valid = pos >= 0
    owned = valid & (pos // n_loc == shard_id)
    sa = owned & (mode == 0)                       # stitch-active
    cur_local = pos - shard_id * n_loc
    rank, _ = rank_within(jnp.where(sa, cur_local, n_loc))
    cl = jnp.clip(jnp.where(sa, cur_local, 0), 0, n_loc - 1)
    offset = next_c[cl] + rank
    ok = sa & (offset < psize[cl])
    cid_loc = jnp.clip(pstart[cl] + offset, 0, S_loc_pad - 1)
    used = used.at[jnp.where(ok, cid_loc, S_loc_pad)].max(
        jnp.ones_like(cid_loc), mode="drop")
    # pool pointer advances by the number of *requests* (the paper deletes
    # coupons on sampling); saturates at the pool size
    req = jax.ops.segment_sum(sa.astype(jnp.int32),
                              jnp.where(sa, cur_local, n_loc),
                              num_segments=n_loc + 1)[:n_loc]
    next_c = jnp.minimum(next_c + req, psize)

    c_dest = dest[cid_loc]
    c_len = clen[cid_loc]
    c_trm = cterm[cid_loc]
    term_now = ok & (c_trm > 0)          # coupon's eps-reset fired: walk done
    lend = jnp.where(ok, lend + c_len, lend)
    new_pos = jnp.where(term_now, -1, jnp.where(ok, c_dest, pos))
    exhaust = sa & ~ok                             # pool empty: naive tail
    mode = jnp.where(exhaust, 1, mode)

    stitched = jax.lax.psum(jnp.sum(ok), AXIS)
    terminated = jax.lax.psum(jnp.sum(term_now), AXIS)
    exhausted = jax.lax.psum(jnp.sum(exhaust), AXIS)
    active = jax.lax.psum(jnp.sum((new_pos >= 0) & (mode == 0)), AXIS)
    dropped = jax.lax.psum(dropped, AXIS)
    waited = jax.lax.psum(waited, AXIS)
    sent = jax.lax.psum(sent, AXIS)
    return (new_pos[None], lend[None], mode[None], next_c[None], used[None],
            active, stitched, terminated, exhausted, dropped, waited, sent)


def _make_p2_step(mesh: Mesh, *, n_loc: int, shards: int, route_cap: int,
                  S_loc_pad: int):
    fn = partial(_p2_local, n_loc=n_loc, shards=shards, route_cap=route_cap,
                 S_loc_pad=S_loc_pad)
    sharded = shard_map(fn, mesh,
                        in_specs=(P(AXIS),) * 10,
                        out_specs=(P(AXIS),) * 5 + (P(),) * 7)

    @jax.jit
    def step(pos, lend, mode, next_c, used, psize, pstart, dest, clen,
             cterm):
        return sharded(pos, lend, mode, next_c, used, psize, pstart, dest,
                       clen, cterm)

    return step


# ---------------------------------------------------------------------------
# estimator reduction
# ---------------------------------------------------------------------------

def _make_finalize(mesh: Mesh, scale: float):
    def fin(zeta):
        z = zeta[0]
        total = jax.lax.psum(jnp.sum(z), AXIS)
        return (z.astype(jnp.float32) * scale)[None], total

    return jax.jit(shard_map(fin, mesh, in_specs=(P(AXIS),),
                             out_specs=(P(AXIS), P())))


# ---------------------------------------------------------------------------
# main driver
# ---------------------------------------------------------------------------

def _lane_cap(requested: Optional[int], load: int, shards: int,
              floor: int = 64) -> int:
    """Single home of the documented lane sizing rule `route_cap >= W/P`.

    With W items resident and P shards, ceil(W/P) slots per (src, dst)
    lane guarantee a full buffer can drain in P rounds even when every
    item targets one shard; floor division under-sizes the lane whenever
    W % P != 0. Defaults are computed with ceil division and the rule is
    asserted for explicit overrides too (an undersized lane only costs
    waiting latency, but it breaks the documented sizing contract)."""
    need = -(-max(int(load), 0) // shards)          # ceil(W / P)
    cap = max(need, floor) if requested is None else int(requested)
    assert cap >= need, (
        f"lane cap {cap} violates route_cap >= ceil(W/P) = {need} "
        f"(W={load}, P={shards})")
    return cap


@dataclasses.dataclass
class ImprovedDistResult:
    zeta: jnp.ndarray            # [n] global visit counts
    pi: jnp.ndarray
    shards: int
    walks_per_node: int
    eps: float
    lam: int
    eta: int
    ell: int
    rounds: int                  # total supersteps across all phases
    phase1_rounds: int
    report_rounds: int
    phase2_rounds: int           # stitch supersteps
    phase3_rounds: int           # replay supersteps (== phase1_rounds)
    tail_rounds: int             # naive-fallback supersteps
    stitch_iterations: int
    exhausted_walks: int
    terminated_by_coupon: int
    tail_walks: int
    coupons_created: int
    coupons_used: int
    dropped: int
    waited: int
    a2a_bytes_total: int
    a2a_bytes_by_phase: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    phase2_records: List[dict] = dataclasses.field(default_factory=list)
    report: Optional[CongestReport] = None
    total_visits: int = 0
    restarts: int = 0            # supervisor recoveries (fault injection)
    checkpoints_written: int = 0


def distributed_improved_pagerank(
    graph: CSRGraph,
    eps: float,
    walks_per_node: Optional[int] = None,
    key: Optional[jnp.ndarray] = None,
    *,
    mesh: Optional[Mesh] = None,
    lam: Optional[int] = None,
    eta: Optional[int] = None,
    eta_safety: float = 2.0,
    cap1: Optional[int] = None,
    cap2: Optional[int] = None,
    route_cap1: Optional[int] = None,
    route_cap2: Optional[int] = None,
    rep_cap: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
) -> ImprovedDistResult:
    """Run Algorithm 2 across all devices of `mesh` (default: all devices).

    With `checkpoint_dir` and/or `fail_at` set, the phase-machine runs
    under the checkpoint-restart supervisor (see `_run_three_phase`)."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    key = key if key is not None else jax.random.PRNGKey(0)
    n = graph.n
    K = walks_per_node or walks_per_node_for(n, eps)
    log_n = math.log(max(n, 2))
    if lam is None:
        lam = max(1, int(math.ceil(math.sqrt(log_n))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))
    eta, pool_np = coupon_pool_sizes(graph, eps, K, lam, eta=eta,
                                     eta_safety=eta_safety)
    return _run_three_phase(
        graph, eps, K, key, mesh, pool_np=pool_np, eta=int(eta),
        lam=int(lam), ell=int(ell), cap1=cap1, cap2=cap2,
        route_cap1=route_cap1, route_cap2=route_cap2, rep_cap=rep_cap,
        max_rounds=max_rounds, bandwidth_bits=bandwidth_bits,
        checkpoint_dir=checkpoint_dir, fail_at=fail_at,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts,
        resume=resume)


def _run_three_phase(
    graph: CSRGraph,
    eps: float,
    K: int,
    key: jnp.ndarray,
    mesh: Mesh,
    *,
    pool_np: np.ndarray,
    eta: int,
    lam: int,
    ell: int,
    cap1: Optional[int] = None,
    cap2: Optional[int] = None,
    route_cap1: Optional[int] = None,
    route_cap2: Optional[int] = None,
    rep_cap: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
    result_cls: type = ImprovedDistResult,
    **extra_fields,
):
    """Budget-policy-agnostic 3-phase stitching driver, structured as a
    checkpointable phase-machine.

    The whole engine — Phase-1 short walks, the closing report exchange,
    Phase-2 stitching, Phase-3 replay counting, the naive tail, and the
    psum-reduced estimator — only ever sees the per-node pool-size vector
    `pool_np`, never the policy that produced it. `distributed_improved_
    pagerank` (Lemma 2, d(v)*eta) and `distributed_directed.distributed_
    directed_pagerank` (Section 5, uniform budgets in the LOCAL model) are
    thin frontends over this core. `result_cls`/`extra_fields` let a
    frontend return a telemetry subclass of ImprovedDistResult.

    Each phase is a `runtime.Stage` over a `StagedState` whose `arrays`
    hold the phase's device buffers and whose `host` dict holds the
    accumulators (round counters, wire volumes, traces, Phase-2 records).
    Without `checkpoint_dir`/`fail_at` the composed `StageSchedule` is
    stepped in a plain loop (no snapshot overhead); with either set, the
    `runtime.Supervisor` drives it with periodic stage-tagged checkpoints
    and (optionally) injected failures at the listed *global* rounds —
    round indices span all phases, so failures can land at phase
    boundaries or mid-phase. Recovery restores the latest snapshot and
    replays the identical trajectory: `zeta`/`pi` and all telemetry are
    bit-identical to an unfailed run. `resume=True` cold-starts from the
    latest snapshot in `checkpoint_dir` (a previously killed run).
    """
    shards = int(mesh.devices.size)
    n = graph.n

    sg = shard_graph(graph, shards)
    n_loc = sg.n_loc
    spec = NamedSharding(mesh, P(AXIS))
    sg_rp = jax.device_put(sg.row_ptr, spec)
    sg_ci = jax.device_put(sg.col_idx, spec)
    sg_dg = jax.device_put(sg.out_deg, spec)

    # ---- coupon pool layout: contiguous per shard, padded to S_loc_pad ----
    pool_pad = np.zeros(sg.n_pad, dtype=np.int64)
    pool_pad[:n] = pool_np
    psize_sh = pool_pad.reshape(shards, n_loc)
    pstart_sh = np.zeros_like(psize_sh)
    pstart_sh[:, 1:] = np.cumsum(psize_sh, axis=1)[:, :-1]
    S_loc = psize_sh.sum(axis=1)
    S_loc_pad = max(int(S_loc.max()), 1)
    S_total = int(pool_np.sum())
    if shards * S_loc_pad >= 2 ** 31:
        raise ValueError("coupon pool too large for int32 ids")

    # lane caps resolve (and assert) the route_cap >= W/P rule in ONE place
    route_cap1 = _lane_cap(route_cap1, S_total, shards)
    route_cap2 = _lane_cap(route_cap2, n * K, shards)
    rep_cap = _lane_cap(rep_cap, S_loc_pad, shards)
    if cap1 is None:
        cap1 = max(2 * S_total // shards, S_loc_pad) + shards * 64
    if cap2 is None:
        cap2 = max(2 * n * K // shards, n_loc * K) + shards * 64

    # ---- Phase-1 initial placement: each coupon at its source vertex ----
    pos0 = np.full((shards, cap1), -1, dtype=np.int32)
    cid0 = np.zeros((shards, cap1), dtype=np.int32)
    for p in range(shards):
        owned = pool_pad[p * n_loc:(p + 1) * n_loc]
        src = np.repeat(np.arange(p * n_loc, (p + 1) * n_loc,
                                  dtype=np.int32), owned)
        assert len(src) <= cap1, "cap1 too small for initial placement"
        pos0[p, : len(src)] = src
        cid0[p, : len(src)] = p * S_loc_pad + np.arange(len(src),
                                                        dtype=np.int32)
    # ---- Phase-2 initial placement: K long walks per real vertex ----
    pos2_np = np.full((shards, cap2), -1, dtype=np.int32)
    for p in range(shards):
        lo = min(p * n_loc, n)
        hi = min((p + 1) * n_loc, n)
        locs = np.repeat(np.arange(lo, hi, dtype=np.int32), K)
        assert len(locs) <= cap2, "cap2 too small for initial placement"
        pos2_np[p, : len(locs)] = locs
    zeta3_np = np.zeros((shards, n_loc), np.int32)
    zeta3_np.reshape(-1)[:n] = K                 # start visits of long walks

    key, k1, k_tail = jax.random.split(key, 3)
    k1_shards = jax.random.split(k1, shards)
    zeros1 = np.zeros((shards, cap1), dtype=np.int32)

    def fresh_p1_state(zeta0: np.ndarray) -> ShortWalkState:
        return ShortWalkState(
            pos=jax.device_put(jnp.asarray(pos0), spec),
            cid=jax.device_put(jnp.asarray(cid0), spec),
            steps=jax.device_put(jnp.asarray(zeros1), spec),
            moves=jax.device_put(jnp.asarray(zeros1), spec),
            alive=jax.device_put(jnp.asarray((pos0 >= 0).astype(np.int32)),
                                 spec),
            key=jax.device_put(k1_shards, spec),
            zeta=jax.device_put(jnp.asarray(zeta0), spec))

    # ---- jitted per-phase step functions (shared by fresh + resumed) ----
    p1_step = _make_p1_step(mesh, eps=float(eps), lam=int(lam), n_loc=n_loc,
                            shards=shards, route_cap=int(route_cap1),
                            count=False)
    rep_step = _make_report_step(mesh, shards=shards, S_loc_pad=S_loc_pad,
                                 rep_cap=int(rep_cap))
    p2_step = _make_p2_step(mesh, n_loc=n_loc, shards=shards,
                            route_cap=int(route_cap2), S_loc_pad=S_loc_pad)
    p3_step = _make_p1_step(mesh, eps=float(eps), lam=int(lam), n_loc=n_loc,
                            shards=shards, route_cap=int(route_cap1),
                            count=True)
    tail_step = _make_superstep(mesh, float(eps), n_loc, shards,
                                int(route_cap2), 0)
    psize_j = jax.device_put(jnp.asarray(psize_sh, dtype=jnp.int32), spec)
    pstart_j = jax.device_put(jnp.asarray(pstart_sh, dtype=jnp.int32), spec)
    no_used = jnp.zeros((1,), jnp.int32)

    _P1_FIELDS = ("pos", "cid", "steps", "moves", "alive", "key", "zeta")

    # ---------------- stage step functions + host transitions ----------
    # Telemetry lives in the JSON-able `host` dict so a restored snapshot
    # rolls the accumulators back in lockstep with the device buffers.

    def _phase1(ms: StagedState):
        st = ShortWalkState(**{f: ms.arrays[f] for f in _P1_FIELDS})
        st, pending, dropped, waited, sent = p1_step(sg_rp, sg_ci, sg_dg,
                                                     st, no_used)
        ms.arrays.update({f: getattr(st, f) for f in _P1_FIELDS})
        h = ms.host
        h["phase1_rounds"] += 1
        h["dropped"] += int(dropped)
        h["waited"] += int(waited)
        entries = int(sent)
        h["wire"]["phase1"] += entries * 20      # pos+cid+steps+moves+alive
        h["traces"].append([int(pending), entries])
        if int(pending) == 0:
            return ms, True
        if h["phase1_rounds"] >= max_rounds:
            raise RuntimeError("phase 1 did not converge within max_rounds")
        return ms, False

    def _after_phase1(ms: StagedState) -> StagedState:
        a = ms.arrays
        zero_pool = jax.device_put(
            jnp.zeros((shards, S_loc_pad), jnp.int32), spec)
        # every live buffer slot holds one (possibly migrated) coupon;
        # empty slots must not report — their cid is stale after compaction
        ms.arrays = dict(pos=a["pos"], cid=a["cid"], moves=a["moves"],
                         alive=a["alive"],
                         pending=(a["pos"] >= 0).astype(jnp.int32),
                         dest=zero_pool, clen=zero_pool, cterm=zero_pool)
        return ms

    def _report(ms: StagedState):
        a = ms.arrays
        pending, dest, clen, cterm, left, sent = rep_step(
            a["pos"], a["cid"], a["moves"], a["alive"], a["pending"],
            a["dest"], a["clen"], a["cterm"])
        a.update(pending=pending, dest=dest, clen=clen, cterm=cterm)
        h = ms.host
        h["report_rounds"] += 1
        entries = int(sent)
        h["wire"]["report"] += entries * 16      # cid+dest+len+term
        h["traces"].append([int(left), entries])
        if int(left) == 0:
            return ms, True
        if h["report_rounds"] >= max_rounds:
            raise RuntimeError("phase-1 report did not converge")
        return ms, False

    def _after_report(ms: StagedState) -> StagedState:
        a = ms.arrays
        zeros2 = jnp.zeros((shards, cap2), jnp.int32)
        ms.arrays = dict(
            pos2=jax.device_put(jnp.asarray(pos2_np), spec),
            lend=jax.device_put(zeros2, spec),
            mode=jax.device_put(zeros2, spec),
            next_c=jax.device_put(jnp.zeros((shards, n_loc), jnp.int32),
                                  spec),
            used=jax.device_put(jnp.zeros((shards, S_loc_pad), jnp.int32),
                                spec),
            dest=a["dest"], clen=a["clen"], cterm=a["cterm"])
        return ms

    def _phase2(ms: StagedState):
        a = ms.arrays
        (pos2, lend, mode, next_c, used, active, stitched, terminated,
         exhausted, dropped, waited, sent) = p2_step(
            a["pos2"], a["lend"], a["mode"], a["next_c"], a["used"],
            psize_j, pstart_j, a["dest"], a["clen"], a["cterm"])
        a.update(pos2=pos2, lend=lend, mode=mode, next_c=next_c, used=used)
        h = ms.host
        h["phase2_rounds"] += 1
        h["stitches"] += int(stitched)
        h["terminated"] += int(terminated)
        h["exhausted"] += int(exhausted)
        h["dropped"] += int(dropped)
        h["waited"] += int(waited)
        entries = int(sent)
        h["wire"]["phase2"] += entries * 12      # pos+len+mode
        h["phase2_records"].append(dict(
            active=int(active), stitched=int(stitched),
            terminated=int(terminated), exhausted=int(exhausted)))
        h["traces"].append([int(active), entries])
        if int(active) == 0:
            return ms, True
        if h["phase2_rounds"] >= max_rounds:
            raise RuntimeError("phase 2 did not converge within max_rounds")
        return ms, False

    def _after_phase2(ms: StagedState) -> StagedState:
        # One broadcast of the used bitmap (charged to Phase-3 wire
        # volume), then a deterministic re-run of the Phase-1 schedule
        # with counting on.
        a = ms.arrays
        h = ms.host
        used_np = np.asarray(a["used"])
        h["coupons_used"] = int(used_np.sum())
        h["wire"]["phase3"] += shards * S_loc_pad * 4
        st3 = fresh_p1_state(zeta3_np)
        ms.arrays = {f: getattr(st3, f) for f in _P1_FIELDS}
        ms.arrays["used_full"] = jnp.asarray(used_np.reshape(-1))
        # pos2/mode ride along untouched: the tail placement needs them
        ms.arrays["pos2"] = a["pos2"]
        ms.arrays["mode"] = a["mode"]
        return ms

    def _phase3(ms: StagedState):
        st = ShortWalkState(**{f: ms.arrays[f] for f in _P1_FIELDS})
        st, pending3, _, _, sent = p3_step(sg_rp, sg_ci, sg_dg, st,
                                           ms.arrays["used_full"])
        ms.arrays.update({f: getattr(st, f) for f in _P1_FIELDS})
        h = ms.host
        h["phase3_rounds"] += 1
        entries = int(sent)
        h["wire"]["phase3"] += entries * 20
        h["traces"].append([int(pending3), entries])
        # the replay costs exactly phase1_rounds supersteps, by schedule
        return ms, h["phase3_rounds"] >= h["phase1_rounds"]

    def _after_phase3(ms: StagedState) -> StagedState:
        a = ms.arrays
        h = ms.host
        pos_tail = jnp.where((a["mode"] == 1) & (a["pos2"] >= 0),
                             a["pos2"], -1)
        h["tail_walks"] = int(jnp.sum(pos_tail >= 0))
        h["tail_active"] = h["tail_walks"]
        ms.arrays = dict(
            pos=jax.device_put(pos_tail, spec),
            zeta=a["zeta"],
            key=jax.device_put(jax.random.split(k_tail, shards), spec),
            round=jnp.int32(0), dropped=jnp.int32(0), waited=jnp.int32(0))
        return ms

    def _tail(ms: StagedState):
        a = ms.arrays
        h = ms.host
        if h["tail_active"]:
            if h["tail_rounds"] >= max_rounds:
                raise RuntimeError(
                    "tail walks did not converge in max_rounds")
            tstate = DistState(pos=a["pos"], zeta=a["zeta"], key=a["key"],
                               round=a["round"], dropped=a["dropped"],
                               waited=a["waited"])
            tstate, active, a2a = tail_step(sg_rp, sg_ci, sg_dg, tstate)
            a.update(pos=tstate.pos, zeta=tstate.zeta, key=tstate.key,
                     round=tstate.round, dropped=tstate.dropped,
                     waited=tstate.waited)
            h["tail_rounds"] += 1
            h["wire"]["tail"] += int(a2a)
            h["traces"].append([int(active), int(a2a) // 4])
            h["tail_active"] = int(active)
        if h["tail_active"]:
            return ms, False
        h["dropped"] += int(a["dropped"])
        h["waited"] += int(a["waited"])
        return ms, True

    schedule = StageSchedule([
        Stage("phase1", _phase1, on_done=_after_phase1),
        Stage("report", _report, on_done=_after_report),
        Stage("phase2", _phase2, on_done=_after_phase2),
        Stage("phase3", _phase3, on_done=_after_phase3),
        Stage("tail", _tail),
    ])

    st0 = fresh_p1_state(np.zeros((shards, n_loc), np.int32))
    ms = StagedState(
        stage=schedule.first_stage,
        arrays={f: getattr(st0, f) for f in _P1_FIELDS},
        host=dict(phase1_rounds=0, report_rounds=0, phase2_rounds=0,
                  phase3_rounds=0, tail_rounds=0, dropped=0, waited=0,
                  stitches=0, terminated=0, exhausted=0, coupons_used=0,
                  tail_walks=0, tail_active=0,
                  wire=dict(phase1=0, report=0, phase2=0, phase3=0, tail=0),
                  traces=[], phase2_records=[]))

    # ---------------- drive: plain loop or checkpointing supervisor ----
    _scalar_keys = ("round", "dropped", "waited")

    def _put(name: str, arr: np.ndarray):
        if name in _scalar_keys or name == "used_full":
            return jnp.asarray(arr)              # replicated scalars/bitmap
        return jax.device_put(jnp.asarray(arr), spec)

    # global rounds sum over the five stages, each bounded by max_rounds
    # (the per-stage guards raise on divergence)
    ms, restarts, checkpoints_written = run_staged(
        schedule, ms, _put, checkpoint_dir=checkpoint_dir, fail_at=fail_at,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts,
        resume=resume, max_rounds=5 * max_rounds + len(schedule.stages),
        tmp_prefix="pr3p_ckpt_")

    # ---------------- estimator: psum-reduced across the mesh ----------
    finalize = _make_finalize(mesh, float(eps) / (n * K))
    pi_sh, total_visits = finalize(ms.arrays["zeta"])
    zeta = ms.arrays["zeta"].reshape(-1)[:n]
    pi = pi_sh.reshape(-1)[:n]

    h = ms.host
    wire = h["wire"]
    rounds = (h["phase1_rounds"] + h["report_rounds"] + h["phase2_rounds"]
              + h["phase3_rounds"] + h["tail_rounds"])
    traces = [RoundTrace(active_walks=a, messages=m, max_edge_count=1,
                         total_count=m) for a, m in h["traces"]]
    report = CongestReport(traces=traces, n=n,
                           bandwidth_bits=bandwidth_bits
                           or default_bandwidth(n))
    return result_cls(
        zeta=zeta, pi=pi, shards=shards, walks_per_node=K, eps=eps,
        lam=int(lam), eta=int(eta), ell=int(ell), rounds=rounds,
        phase1_rounds=h["phase1_rounds"], report_rounds=h["report_rounds"],
        phase2_rounds=h["phase2_rounds"], phase3_rounds=h["phase3_rounds"],
        tail_rounds=h["tail_rounds"], stitch_iterations=h["phase2_rounds"],
        exhausted_walks=h["exhausted"],
        terminated_by_coupon=h["terminated"], tail_walks=h["tail_walks"],
        coupons_created=S_total, coupons_used=h["coupons_used"],
        dropped=h["dropped"], waited=h["waited"],
        a2a_bytes_total=sum(wire.values()), a2a_bytes_by_phase=wire,
        phase2_records=h["phase2_records"], report=report,
        total_visits=int(total_visits), restarts=restarts,
        checkpoints_written=checkpoints_written, **extra_fields)
