"""Shared CONGEST routing machinery for the shard_map engines.

Every multi-device engine in this repo (Algorithm 1 walk-routing in
`distributed.py`, count-aggregation in `distributed_counts.py`, Algorithm 2
in `distributed_improved.py`) moves data between vertex shards with the same
static-shape discipline:

  * per (src_shard, dst_shard) routing lanes of fixed capacity — one
    `all_to_all` per exchange, payload slots that did not fill carry the
    sentinel value;
  * a stable sort-and-rank to assign each outgoing item a distinct lane
    slot for its target shard; items beyond the lane capacity *wait* and
    are retried next round (correctness preserved, only latency paid);
  * walk buffers of fixed capacity `cap`, compacted after each merge, with
    overflow counted in `dropped` (must stay 0 under the sizing rule
    `cap >= 2*W/P + P*route_cap`).

This module owns that machinery so the engines share one implementation:
`rank_within` (stable in-group ranks), `pack_lanes`/`exchange` (lane
scatter + all_to_all), `route_walks`/`merge_walks` (full route superstep for
walk buffers with arbitrary payload fields riding along), `advance_owned`
(one eps-reset/uniform-out-edge PageRank step for owned walks) and
`count_owned_arrivals` (owner-side visit accounting).

All helpers run *inside* shard_map: `jax.lax.axis_index`/`all_to_all` refer
to the mesh axis passed as `axis`.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 stable API
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        # check_vma=False: jax.random.binomial's internal while_loop mixes
        # varying/invariant carries under the VMA checker; collectives in
        # our supersteps are explicit (psum/all_to_all), so the check adds
        # nothing.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def rank_within(sort_key: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For each element, its rank within its equal-key group (stable).

    Returns (rank, order): `rank[i]` is the 0-based position of element i
    among elements with the same `sort_key`, `order` is the stable argsort.

    Stability is load-bearing, not cosmetic: `lane_slots`' zero-drop
    property, natural-order coupon consumption in Phase 2, and the
    Phase-3 deterministic replay all require equal keys to keep buffer
    order — so it is requested explicitly rather than relying on the
    jnp.argsort default.
    """
    W = sort_key.shape[0]
    order = jnp.argsort(sort_key, stable=True)
    sorted_k = sort_key[order]
    idx = jnp.arange(W)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_k[1:] != sorted_k[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    rank = jnp.zeros((W,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    return rank, order


def lane_slots(target: jnp.ndarray, valid: jnp.ndarray, num_targets: int,
               lane_cap: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign each valid item a distinct (target, rank) lane slot.

    Returns (sendable, flat_idx): `sendable` marks items that fit their
    target's lane this round; `flat_idx` is the scatter index into a
    [num_targets * lane_cap] lane array (non-sendable items point at the
    sentinel slot one past the end — scatter with mode="drop").
    """
    sort_key = jnp.where(valid, target, num_targets)  # invalid sort last
    rank, _ = rank_within(sort_key)
    sendable = valid & (rank < lane_cap)
    flat_idx = jnp.where(sendable, target * lane_cap + rank,
                         num_targets * lane_cap)
    return sendable, flat_idx


def pack_lanes(flat_idx: jnp.ndarray, values: jnp.ndarray,
               sendable: jnp.ndarray, num_targets: int, lane_cap: int,
               fill: int = -1) -> jnp.ndarray:
    """Scatter `values[sendable]` into a [num_targets * lane_cap] lane array."""
    return (jnp.full((num_targets * lane_cap,), fill, dtype=jnp.int32)
            .at[flat_idx].set(jnp.where(sendable, values, fill), mode="drop"))


def exchange(lanes: jnp.ndarray, axis: str, num_targets: int,
             lane_cap: int) -> jnp.ndarray:
    """all_to_all a flat [num_targets * lane_cap] lane array; returns the
    received lanes flattened back to [num_targets * lane_cap]."""
    return jax.lax.all_to_all(lanes.reshape(num_targets, lane_cap), axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(-1)


def exchange_stacked(lanes: list, axis: str, num_targets: int,
                     lane_cap: int) -> list:
    """all_to_all several same-shape lane arrays as ONE collective: slots
    are interleaved so each (target, slot) carries its F payload columns
    contiguously. Values are identical to F separate `exchange` calls —
    this only collapses F collective launches into one."""
    stacked = jnp.stack(lanes, axis=-1)        # [num_targets*lane_cap, F]
    F = stacked.shape[-1]
    recv = jax.lax.all_to_all(
        stacked.reshape(num_targets, lane_cap * F), axis,
        split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(num_targets * lane_cap, F)
    return [recv[:, i] for i in range(F)]


def route_walks(pos: jnp.ndarray, fields: Dict[str, jnp.ndarray], *,
                axis: str, shard_id: jnp.ndarray, n_loc: int, shards: int,
                route_cap: int):
    """One routing exchange: send walks whose current vertex is owned by
    another shard (up to `route_cap` per target; the rest wait).

    `fields` are extra int32 payload columns riding along with `pos`
    (coupon ids, lengths, flags, ...). Returns
    (kept_pos, kept_fields, recv_pos, recv_fields, waited, sent_entries);
    `recv_*` are [shards * route_cap] with -1 in empty `recv_pos` slots.
    """
    valid = pos >= 0
    owner = jnp.where(valid, pos // n_loc, shards)
    needs = valid & (owner != shard_id)
    sendable, flat_idx = lane_slots(owner, needs, shards, route_cap)
    send_pos = pack_lanes(flat_idx, pos, sendable, shards, route_cap)
    if fields:
        send_f = [pack_lanes(flat_idx, vals, sendable, shards, route_cap,
                             fill=0) for vals in fields.values()]
        recvs = exchange_stacked([send_pos] + send_f, axis, shards,
                                 route_cap)
        recv_pos = recvs[0]
        recv_fields = dict(zip(fields.keys(), recvs[1:]))
    else:
        recv_pos = exchange(send_pos, axis, shards, route_cap)
        recv_fields = {}
    kept_pos = jnp.where(sendable, -1, pos)  # sent slots freed
    kept_fields = {name: jnp.where(sendable, 0, vals)
                   for name, vals in fields.items()}
    waited = jnp.sum(needs & ~sendable)
    sent_entries = jnp.sum(send_pos >= 0)
    return kept_pos, kept_fields, recv_pos, recv_fields, waited, sent_entries


def merge_walks(kept_pos: jnp.ndarray, kept_fields: Dict[str, jnp.ndarray],
                recv_pos: jnp.ndarray, recv_fields: Dict[str, jnp.ndarray],
                cap: int):
    """Compact kept walks + arrivals into the fixed-capacity buffer.

    Valid walks sort first (stable), so arrivals beyond `cap` are the ones
    dropped; returns (pos, fields, dropped)."""
    arrived = recv_pos >= 0
    merged_pos = jnp.concatenate([kept_pos, jnp.where(arrived, recv_pos, -1)])
    order = jnp.argsort(jnp.where(merged_pos >= 0, 0, 1), stable=True)
    merged_pos = merged_pos[order]
    total_valid = jnp.sum(merged_pos >= 0)
    dropped = jnp.maximum(total_valid - cap, 0)
    fields = {}
    for name in kept_fields:
        merged = jnp.concatenate([kept_fields[name], recv_fields[name]])
        fields[name] = merged[order][:cap]
    return merged_pos[:cap], fields, dropped


def count_owned_arrivals(mask: jnp.ndarray, v_global: jnp.ndarray,
                         shard_id: jnp.ndarray, n_loc: int) -> jnp.ndarray:
    """[n_loc] histogram of `v_global[mask]` rebased to this shard's range
    (masked entries dump into a discarded overflow segment)."""
    return jax.ops.segment_sum(
        mask.astype(jnp.int32),
        jnp.where(mask, v_global - shard_id * n_loc, n_loc),
        num_segments=n_loc + 1)[:n_loc]


def advance_owned(rp: jnp.ndarray, ci: jnp.ndarray, dg: jnp.ndarray,
                  pos: jnp.ndarray, eligible: jnp.ndarray,
                  k_term: jnp.ndarray, k_edge: jnp.ndarray, eps: float,
                  shard_id: jnp.ndarray, n_loc: int):
    """One PageRank step for the `eligible` walks of this shard: terminate
    w.p. eps (or on a dangling vertex), else move along a uniform out-edge.

    Returns (survive, dst): `survive` marks walks that moved, `dst` their
    new global vertex (meaningful only where `survive`)."""
    cap = pos.shape[0]
    local = jnp.where(eligible, pos - shard_id * n_loc, 0)
    deg = dg[local]
    u_term = jax.random.uniform(k_term, (cap,))
    survive = eligible & (u_term >= eps) & (deg > 0)
    u_edge = jax.random.uniform(k_edge, (cap,))
    j = jnp.minimum((u_edge * jnp.maximum(deg, 1)).astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
    eid = jnp.clip(rp[local] + j, 0, ci.shape[0] - 1)
    dst = ci[eid]
    return survive, dst
