"""Shared CONGEST routing machinery for the shard_map engines.

Every multi-device engine in this repo (Algorithm 1 walk-routing in
`distributed.py`, count-aggregation in `distributed_counts.py`, Algorithm 2
in `distributed_improved.py`) moves data between vertex shards with the same
static-shape discipline:

  * per (src_shard, dst_shard) routing lanes of fixed capacity — one
    `all_to_all` per exchange, payload slots that did not fill carry the
    sentinel value;
  * a stable sort-and-rank to assign each outgoing item a distinct lane
    slot for its target shard; items beyond the lane capacity *wait* and
    are retried next round (correctness preserved, only latency paid);
  * walk buffers of fixed capacity `cap`, compacted after each merge, with
    overflow counted in `dropped` (must stay 0 under the sizing rule
    `cap >= 2*W/P + P*route_cap`).

This module owns that machinery so the engines share one implementation:
`rank_within` (stable in-group ranks), `pack_lanes`/`exchange` (lane
scatter + all_to_all), `route_walks`/`merge_walks` (full route superstep for
walk buffers with arbitrary payload fields riding along), `route_counts`
(the Lemma-1 count-aggregated exchange: per-destination-vertex counts as
(vertex, count) lanes, payload independent of how many walks move),
`advance_owned` (one eps-reset/uniform-out-edge PageRank step for owned
walks) and `count_owned_arrivals` (owner-side visit accounting).

Wire accounting: `entry_nbytes` is the single source of truth for
bytes-per-lane-entry — it is derived from the dtypes of the arrays actually
exchanged, and the routing helpers return `sent_bytes` computed with it, so
an engine's wire telemetry cannot drift from its payload when a column is
added or dropped.

`advance_owned` and `count_owned_arrivals` accept `use_pallas` to run the
per-walk advancement / histogram through the Pallas kernels in
`repro.kernels` (`walk_step`, `histogram`); the kernels are bit-identical
to the jnp paths (same uniforms, same decision logic) and fall back to
interpret mode off-TPU.

All helpers run *inside* shard_map: `jax.lax.axis_index`/`all_to_all` refer
to the mesh axis passed as `axis`.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import histogram as _histogram_kernel
from repro.kernels import segment_spmv as _segment_spmv_kernel
from repro.kernels import walk_step as _walk_step_kernel

try:  # jax >= 0.6 stable API
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        # check_vma=False: jax.random.binomial's internal while_loop mixes
        # varying/invariant carries under the VMA checker; collectives in
        # our supersteps are explicit (psum/all_to_all), so the check adds
        # nothing.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def rank_within(sort_key: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For each element, its rank within its equal-key group (stable).

    Returns (rank, order): `rank[i]` is the 0-based position of element i
    among elements with the same `sort_key`, `order` is the stable argsort.

    Stability is load-bearing, not cosmetic: `lane_slots`' zero-drop
    property, natural-order coupon consumption in Phase 2, and the
    Phase-3 deterministic replay all require equal keys to keep buffer
    order — so it is requested explicitly rather than relying on the
    jnp.argsort default.
    """
    W = sort_key.shape[0]
    order = jnp.argsort(sort_key, stable=True)
    sorted_k = sort_key[order]
    idx = jnp.arange(W)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_k[1:] != sorted_k[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    rank = jnp.zeros((W,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    return rank, order


def lane_slots(target: jnp.ndarray, valid: jnp.ndarray, num_targets: int,
               lane_cap: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign each valid item a distinct (target, rank) lane slot.

    Returns (sendable, flat_idx): `sendable` marks items that fit their
    target's lane this round; `flat_idx` is the scatter index into a
    [num_targets * lane_cap] lane array (non-sendable items point at the
    sentinel slot one past the end — scatter with mode="drop").
    """
    sort_key = jnp.where(valid, target, num_targets)  # invalid sort last
    rank, _ = rank_within(sort_key)
    sendable = valid & (rank < lane_cap)
    flat_idx = jnp.where(sendable, target * lane_cap + rank,
                         num_targets * lane_cap)
    return sendable, flat_idx


def pack_lanes(flat_idx: jnp.ndarray, values: jnp.ndarray,
               sendable: jnp.ndarray, num_targets: int, lane_cap: int,
               fill: int = -1) -> jnp.ndarray:
    """Scatter `values[sendable]` into a [num_targets * lane_cap] lane array."""
    return (jnp.full((num_targets * lane_cap,), fill, dtype=jnp.int32)
            .at[flat_idx].set(jnp.where(sendable, values, fill), mode="drop"))


def exchange(lanes: jnp.ndarray, axis: str, num_targets: int,
             lane_cap: int) -> jnp.ndarray:
    """all_to_all a flat [num_targets * lane_cap] lane array; returns the
    received lanes flattened back to [num_targets * lane_cap]."""
    return jax.lax.all_to_all(lanes.reshape(num_targets, lane_cap), axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(-1)


def exchange_stacked(lanes: list, axis: str, num_targets: int,
                     lane_cap: int) -> list:
    """all_to_all several same-shape lane arrays as ONE collective: slots
    are interleaved so each (target, slot) carries its F payload columns
    contiguously. Values are identical to F separate `exchange` calls —
    this only collapses F collective launches into one."""
    stacked = jnp.stack(lanes, axis=-1)        # [num_targets*lane_cap, F]
    F = stacked.shape[-1]
    recv = jax.lax.all_to_all(
        stacked.reshape(num_targets, lane_cap * F), axis,
        split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(num_targets * lane_cap, F)
    return [recv[:, i] for i in range(F)]


def entry_nbytes(*columns) -> int:
    """Bytes per lane entry: the sum of the dtype sizes of the payload
    columns actually exchanged (dicts of columns count every value).

    The single home of wire accounting — engines charge
    `sent_entries * entry_nbytes(<the exchanged arrays>)`, so the telemetry
    bytes track the payload by construction instead of via hand-maintained
    magic constants.
    """
    total = 0
    for col in columns:
        if isinstance(col, dict):
            total += sum(jnp.asarray(v).dtype.itemsize for v in col.values())
        else:
            total += jnp.asarray(col).dtype.itemsize
    return int(total)


def _seg_reduce(values: jnp.ndarray, seg: jnp.ndarray, num_segments: int,
                use_pallas: bool, count_bound=None) -> jnp.ndarray:
    """Sum `values` into `num_segments` buckets; out-of-range seg ids drop.

    With `use_pallas` the reduction runs through the `segment_spmv` kernel
    (fp32 accumulation — exact for integer counts below 2**24). Engines
    declare the largest reachable count via `count_bound`; past 2**24 the
    kernel wrapper widens to an exact integer reduction instead of
    truncating (see `kernels/segment_spmv/ops.py`)."""
    if use_pallas:
        return _segment_spmv_kernel(values, seg, num_segments,
                                    count_bound=count_bound
                                    ).astype(values.dtype)
    return jax.ops.segment_sum(values, jnp.where(
        (seg >= 0) & (seg < num_segments), seg, num_segments),
        num_segments=num_segments + 1)[:num_segments]


def vertex_histogram(v: jnp.ndarray, mask: jnp.ndarray, num_vertices: int,
                     use_pallas: bool = False) -> jnp.ndarray:
    """[num_vertices] histogram of `v[mask]` (any shape, flattened).

    The per-vertex count builder feeding `route_counts`; `use_pallas`
    runs it through the `histogram` kernel."""
    v = v.reshape(-1)
    mask = mask.reshape(-1)
    if use_pallas:
        return _histogram_kernel(jnp.where(mask, v, -1), num_vertices)
    return jax.ops.segment_sum(
        mask.astype(jnp.int32),
        jnp.where(mask & (v >= 0) & (v < num_vertices), v, num_vertices),
        num_segments=num_vertices + 1)[:num_vertices]


def route_counts(per_vertex: jnp.ndarray, *, axis: str,
                 shard_id: jnp.ndarray, n_loc: int, shards: int,
                 by_source: bool = False, use_pallas: bool = False,
                 count_bound=None):
    """One Lemma-1 aggregated exchange: per-destination-vertex counts
    travel as (vertex, count) pairs — payload bounded by the number of
    distinct destination vertices, independent of how many walks move.

    `per_vertex` is a [shards * n_loc] int32 count vector indexed by global
    (padded) vertex id. Counts destined for vertices this shard owns are
    applied locally and never hit the wire. At most `n_loc` distinct
    vertices can target one owner, so the built-in lane capacity of `n_loc`
    makes lane overflow structurally impossible (no waiting, no dropping).

    Returns (arrivals, sent_entries, sent_bytes): `arrivals` is the
    [n_loc] count of items delivered to each owned vertex, or
    [shards, n_loc] broken down by source shard when `by_source` (the own
    shard's contribution sits in row `shard_id`).
    """
    n_pad = shards * n_loc
    vid = jnp.arange(n_pad, dtype=jnp.int32)
    owner = vid // n_loc
    own = per_vertex.reshape(shards, n_loc)[shard_id]
    remote = (owner != shard_id) & (per_vertex > 0)
    sendable, flat_idx = lane_slots(owner, remote, shards, n_loc)
    lanes_v = pack_lanes(flat_idx, vid, sendable, shards, n_loc, fill=-1)
    lanes_c = pack_lanes(flat_idx, per_vertex, sendable, shards, n_loc,
                         fill=0)
    recv_v, recv_c = exchange_stacked([lanes_v, lanes_c], axis, shards,
                                      n_loc)
    got = recv_v >= 0
    sent_entries = jnp.sum(lanes_v >= 0)
    sent_bytes = sent_entries * entry_nbytes(lanes_v, lanes_c)
    local_v = recv_v - shard_id * n_loc          # in [0, n_loc) where got
    cnt = jnp.where(got, recv_c, 0)
    if by_source:
        src = jnp.arange(shards * n_loc, dtype=jnp.int32) // n_loc
        seg = jnp.where(got, src * n_loc + local_v, n_pad)
        arrivals = _seg_reduce(cnt, seg, n_pad, use_pallas,
                               count_bound).reshape(shards, n_loc)
        arrivals = arrivals.at[shard_id].add(own)
    else:
        seg = jnp.where(got, local_v, n_loc)
        arrivals = _seg_reduce(cnt, seg, n_loc, use_pallas, count_bound) + own
    return arrivals, sent_entries, sent_bytes


def route_walks(pos: jnp.ndarray, fields: Dict[str, jnp.ndarray], *,
                axis: str, shard_id: jnp.ndarray, n_loc: int, shards: int,
                route_cap: int):
    """One routing exchange: send walks whose current vertex is owned by
    another shard (up to `route_cap` per target; the rest wait).

    `fields` are extra int32 payload columns riding along with `pos`
    (coupon ids, lengths, flags, ...). Returns (kept_pos, kept_fields,
    recv_pos, recv_fields, waited, sent_entries, sent_bytes); `recv_*` are
    [shards * route_cap] with -1 in empty `recv_pos` slots, and
    `sent_bytes` charges `entry_nbytes` over the columns actually shipped.
    """
    valid = pos >= 0
    owner = jnp.where(valid, pos // n_loc, shards)
    needs = valid & (owner != shard_id)
    sendable, flat_idx = lane_slots(owner, needs, shards, route_cap)
    send_pos = pack_lanes(flat_idx, pos, sendable, shards, route_cap)
    if fields:
        send_f = [pack_lanes(flat_idx, vals, sendable, shards, route_cap,
                             fill=0) for vals in fields.values()]
        recvs = exchange_stacked([send_pos] + send_f, axis, shards,
                                 route_cap)
        recv_pos = recvs[0]
        recv_fields = dict(zip(fields.keys(), recvs[1:]))
    else:
        recv_pos = exchange(send_pos, axis, shards, route_cap)
        recv_fields = {}
    kept_pos = jnp.where(sendable, -1, pos)  # sent slots freed
    kept_fields = {name: jnp.where(sendable, 0, vals)
                   for name, vals in fields.items()}
    waited = jnp.sum(needs & ~sendable)
    sent_entries = jnp.sum(send_pos >= 0)
    sent_bytes = sent_entries * entry_nbytes(pos, fields)
    return (kept_pos, kept_fields, recv_pos, recv_fields, waited,
            sent_entries, sent_bytes)


def merge_walks(kept_pos: jnp.ndarray, kept_fields: Dict[str, jnp.ndarray],
                recv_pos: jnp.ndarray, recv_fields: Dict[str, jnp.ndarray],
                cap: int):
    """Compact kept walks + arrivals into the fixed-capacity buffer.

    Valid walks sort first (stable), so arrivals beyond `cap` are the ones
    dropped; returns (pos, fields, dropped)."""
    arrived = recv_pos >= 0
    merged_pos = jnp.concatenate([kept_pos, jnp.where(arrived, recv_pos, -1)])
    order = jnp.argsort(jnp.where(merged_pos >= 0, 0, 1), stable=True)
    merged_pos = merged_pos[order]
    total_valid = jnp.sum(merged_pos >= 0)
    dropped = jnp.maximum(total_valid - cap, 0)
    fields = {}
    for name in kept_fields:
        merged = jnp.concatenate([kept_fields[name], recv_fields[name]])
        fields[name] = merged[order][:cap]
    return merged_pos[:cap], fields, dropped


def count_owned_arrivals(mask: jnp.ndarray, v_global: jnp.ndarray,
                         shard_id: jnp.ndarray, n_loc: int,
                         use_pallas: bool = False) -> jnp.ndarray:
    """[n_loc] histogram of `v_global[mask]` rebased to this shard's range
    (masked entries dump into a discarded overflow segment)."""
    local = jnp.where(mask, v_global - shard_id * n_loc, -1)
    if use_pallas:
        return _histogram_kernel(local, n_loc)
    return jax.ops.segment_sum(
        mask.astype(jnp.int32), jnp.where(mask, local, n_loc),
        num_segments=n_loc + 1)[:n_loc]


def advance_owned(rp: jnp.ndarray, ci: jnp.ndarray, dg: jnp.ndarray,
                  pos: jnp.ndarray, eligible: jnp.ndarray,
                  k_term: jnp.ndarray, k_edge: jnp.ndarray, eps: float,
                  shard_id: jnp.ndarray, n_loc: int,
                  use_pallas: bool = False):
    """One PageRank step for the `eligible` walks of this shard: terminate
    w.p. eps (or on a dangling vertex), else move along a uniform out-edge.

    Returns (survive, dst): `survive` marks walks that moved, `dst` their
    new global vertex (meaningful only where `survive`). The `use_pallas`
    path draws the SAME uniforms and applies the same decision logic inside
    the `walk_step` kernel, so both paths are bit-identical."""
    cap = pos.shape[0]
    local = jnp.where(eligible, pos - shard_id * n_loc, 0)
    u_term = jax.random.uniform(k_term, (cap,))
    u_edge = jax.random.uniform(k_edge, (cap,))
    if use_pallas:
        new_pos, new_alive = _walk_step_kernel(
            local, eligible.astype(jnp.int32), u_term, u_edge, rp, ci, dg,
            eps=eps)
        return new_alive != 0, new_pos
    deg = dg[local]
    survive = eligible & (u_term >= eps) & (deg > 0)
    j = jnp.minimum((u_edge * jnp.maximum(deg, 1)).astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
    eid = jnp.clip(rp[local] + j, 0, ci.shape[0] - 1)
    dst = ci[eid]
    return survive, dst
