"""CONGEST-model accounting.

The paper's efficiency claims are about *rounds* of an n-processor network
with B = polylog(n) bits per edge per round. On a TPU we execute
bulk-synchronous super-steps instead, so the theorems are validated through a
pure accounting layer: every engine reports, per logical round, the maximum
count value sent over any edge and aggregate message statistics; this module
converts those traces into CONGEST(B) round counts.

Message encoding model (matches the paper):
  a coupon-count message of value T costs ceil(log2(T+1)) + O(1) bits; an
  edge carries one count per direction per round (Lemma 1 — counts, never
  walk identities).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np


@dataclasses.dataclass
class RoundTrace:
    """Statistics of one logical round of a walk engine."""

    active_walks: int          # walks alive at the start of the round
    messages: int              # number of (edge, direction) count messages
    max_edge_count: int        # largest count carried by any single edge
    total_count: int           # sum of all counts moved (== surviving walks)

    @property
    def max_edge_bits(self) -> int:
        # ceil(log2(T+1)) payload + 8-bit header
        return int(math.ceil(math.log2(self.max_edge_count + 1))) + 8 if self.max_edge_count else 0


@dataclasses.dataclass
class CongestReport:
    traces: List[RoundTrace]
    n: int
    bandwidth_bits: int  # B

    @property
    def logical_rounds(self) -> int:
        return len(self.traces)

    @property
    def congest_rounds(self) -> int:
        """Rounds after splitting any over-B edge payload across rounds."""
        total = 0
        for t in self.traces:
            total += max(1, math.ceil(max(t.max_edge_bits, 1) / self.bandwidth_bits))
        return total

    @property
    def max_bits_per_edge_per_round(self) -> int:
        return max((t.max_edge_bits for t in self.traces), default=0)

    @property
    def total_message_bits(self) -> int:
        return sum(t.messages * max(t.max_edge_bits, 1) for t in self.traces)

    def summary(self) -> dict:
        return dict(
            n=self.n,
            logical_rounds=self.logical_rounds,
            congest_rounds=self.congest_rounds,
            max_bits_per_edge_per_round=self.max_bits_per_edge_per_round,
            bandwidth_bits=self.bandwidth_bits,
        )


def default_bandwidth(n: int) -> int:
    """B = Theta(log^2 n) bits — a standard CONGEST(polylog) instantiation."""
    return max(32, int(math.ceil(math.log2(max(n, 2)) ** 2)))


def phase_rounds_constant(num_events: int) -> List[RoundTrace]:
    """O(1)-round direct-communication events (Phase-2 stitches): each event
    is one token message of O(log n) bits — under-B by construction."""
    return [RoundTrace(active_walks=num_events, messages=num_events, max_edge_count=1, total_count=num_events)]
