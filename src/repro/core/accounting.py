"""CONGEST-model accounting.

The paper's efficiency claims are about *rounds* of an n-processor network
with B = polylog(n) bits per edge per round. On a TPU we execute
bulk-synchronous super-steps instead, so the theorems are validated through a
pure accounting layer: every engine reports, per logical round, the maximum
count value sent over any edge and aggregate message statistics; this module
converts those traces into CONGEST(B) round counts.

Message encoding model (matches the paper):
  a coupon-count message of value T costs ceil(log2(T+1)) + O(1) bits; an
  edge carries one count per direction per round (Lemma 1 — counts, never
  walk identities).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RoundTrace:
    """Statistics of one logical round of a walk engine."""

    active_walks: int          # walks alive at the start of the round
    messages: int              # number of (edge, direction) count messages
    max_edge_count: int        # largest count carried by any single edge
    total_count: int           # sum of all counts moved (== surviving walks)

    @property
    def max_edge_bits(self) -> int:
        # ceil(log2(T+1)) payload + 8-bit header
        return int(math.ceil(math.log2(self.max_edge_count + 1))) + 8 if self.max_edge_count else 0


@dataclasses.dataclass
class CongestReport:
    traces: List[RoundTrace]
    n: int
    bandwidth_bits: int  # B

    @property
    def logical_rounds(self) -> int:
        return len(self.traces)

    @property
    def congest_rounds(self) -> int:
        """Rounds after splitting any over-B edge payload across rounds."""
        total = 0
        for t in self.traces:
            total += max(1, math.ceil(max(t.max_edge_bits, 1) / self.bandwidth_bits))
        return total

    @property
    def max_bits_per_edge_per_round(self) -> int:
        return max((t.max_edge_bits for t in self.traces), default=0)

    @property
    def total_message_bits(self) -> int:
        return sum(t.messages * max(t.max_edge_bits, 1) for t in self.traces)

    def summary(self) -> dict:
        return dict(
            n=self.n,
            logical_rounds=self.logical_rounds,
            congest_rounds=self.congest_rounds,
            max_bits_per_edge_per_round=self.max_bits_per_edge_per_round,
            bandwidth_bits=self.bandwidth_bits,
        )


def default_bandwidth(n: int) -> int:
    """B = Theta(log^2 n) bits — a standard CONGEST(polylog) instantiation."""
    return max(32, int(math.ceil(math.log2(max(n, 2)) ** 2)))


def phase_rounds_constant(num_events: int) -> List[RoundTrace]:
    """O(1)-round direct-communication events (Phase-2 stitches): each event
    is one token message of O(log n) bits — under-B by construction."""
    return [RoundTrace(active_walks=num_events, messages=num_events, max_edge_count=1, total_count=num_events)]


# ---------------------------------------------------------------------------
# Static wire-budget declarations (consumed by `analysis.congest`)
#
# Every distributed engine exposes an `audit_spec(graph, mesh, ...)` that
# returns an `EngineAuditSpec`: its jitted stage programs with trace-ready
# example shapes, plus one `ExchangeSite` per all_to_all the program is
# *supposed* to launch, carrying the declared per-entry width and a
# W-free lane budget (a function of distinct vertices and polylog(n)
# factors — never of the walk multiplicity W). The auditor traces the
# programs to jaxprs and machine-checks the declarations against the
# collectives actually compiled. These types live here (not in analysis/)
# so core engines can declare budgets without importing the analyzer.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExchangeSite:
    """One declared all_to_all exchange of a stage program.

    `lane_entries` is the per-shard-per-round lane capacity actually
    compiled (total slots of the a2a operand); `budget_entries` is the
    W-free bound it must never exceed, with `budget_formula` naming the
    closed form. `wire_class` is "count" for Lemma-1 (vertex, count)
    payloads and "walk" for the per-walk lanes of the naive engines,
    whose runtime caps scale with W/P — the auditor pins those at n_loc
    when tracing, so the *checked* capacity stays W-free.
    """

    site: str                  # telemetry key, e.g. "phase1_rep"
    entry_nbytes: int          # declared wire bytes per lane entry
    lane_entries: int          # compiled lane slots per shard per round
    budget_entries: int        # W-free bound on lane_entries
    budget_formula: str        # human-readable closed form of the budget
    wire_class: str = "count"  # "count" (Lemma 1) | "walk" (naive lanes)
    note: str = ""

    @property
    def capacity_bytes(self) -> int:
        return self.entry_nbytes * self.lane_entries

    @property
    def budget_bytes(self) -> int:
        return self.entry_nbytes * self.budget_entries


@dataclasses.dataclass(frozen=True)
class StageProgram:
    """One traceable jitted program of a `runtime.Stage`.

    `fn(*example_args)` must be jaxpr-traceable (example_args are
    ShapeDtypeStruct pytrees); `sites` lists the expected all_to_all
    launches in program order. `count_bound` declares the largest integer
    count the program can move — the dtype lint flags int->float funnels
    only when this bound exceeds the target float's exact-integer range.
    """

    stage: str                          # runtime.Stage name
    program: str                        # program within the stage
    fn: Any                             # jitted callable
    example_args: Tuple[Any, ...]       # ShapeDtypeStruct pytrees
    sites: Tuple[ExchangeSite, ...] = ()
    count_bound: Optional[int] = None


@dataclasses.dataclass
class EngineAuditSpec:
    """A distributed engine's complete audit declaration: every stage
    program with its wire budgets, plus the `StagedState` array names and
    `checkpoint.LayoutSpec` schema per stage (kept opaque here — the
    elastic-schema lint compares them structurally)."""

    engine: str
    programs: List[StageProgram]
    stage_arrays: Dict[str, Tuple[str, ...]]
    layouts: Dict[str, Dict[str, Any]]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
