"""Multi-device Section-5 engine — directed graphs in the LOCAL model.

This fills the last cell of the ROADMAP engine matrix: the shard_map
realization of the paper's Section-5 extension of IMPROVED-PAGERANK to
directed graphs. It shares the entire 3-phase machinery with the
Algorithm-2 engine (`distributed_improved._run_three_phase`, built on the
lane/route/exchange primitives in `routing.py`); what Section 5 changes is
the *budget policy* and the *round budget*, not the supersteps:

  Uniform coupon budgets. On a directed graph there is no Lemma-2 bound
    relating walk visits to d(v) (short PageRank walks are not near
    degree-stationary), so Phase 1 cannot size vertex v's pool as
    d(v)*eta. Every node instead precomputes the same
    eta*ceil(log n) short walks (`coupon_pool_sizes(...,
    degree_proportional=False)`), the LOCAL-model analogue of the paper's
    "polynomially many coupons per node".

  Longer short walks. With uniform budgets the optimal split of the
    length-ell long walk is lam = ceil(sqrt(log n / eps)) — the Section-5
    round bound O(sqrt(log n / eps)) — instead of the CONGEST
    lam = ceil(sqrt(log n)).

  Directed out-edges only, dangling resets. Walks move along the CSR
    out-edges exactly as written (nothing is symmetrized), and a walk
    arriving at a dangling node (out-degree 0) takes an immediate reset —
    the owner-side aggregate sampler terminates the whole dangling row,
    the same convention as `graph.transition_matrix` (dangling row =
    uniform teleport), so the estimator stays consistent with power
    iteration.

This engine used to default to worst-case LOCAL buffers (every coupon /
walk co-resident on one shard) because a directed hub can attract
essentially the whole pool in one round and per-walk lanes under the
CONGEST 2*W/P rule overflowed on power-law webs. Count aggregation
(Lemma 1) retired the pool-sized buffers: Phases 1-3 move per-vertex
counts whose lane volume is bounded by distinct vertices, never by walk
multiplicity, so a hub attracting the entire pool still costs ONE lane
entry and no per-coupon slot exists anywhere (the old cap1 was
sum(pool) ~ n*eta*log n slots per shard). The one per-walk surface left
is the naive exhaustion tail, and there a directed hub still has no
degree bound tying its load to 2*W/P — so `cap2` alone keeps the
worst-case W sizing (W = n*K walk slots, orders of magnitude below the
retired pool buffers), which makes `dropped == 0` structural: lane
backpressure shows up as `waited`, never as a drop.

Phase structure, wire accounting, conservation counters, the exhaustion
fallback to naive distributed walking, and the host-float64 estimator
pi = zeta * eps/(nK) are identical to `distributed_improved.py` — see
that module for the superstep details. Statistical target:
`improved_pagerank.directed_local_pagerank` (the single-device Section-5
engine) and power iteration on directed fixtures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.distributed import AXIS
from repro.core.distributed_improved import (ImprovedDistResult,
                                             _run_three_phase,
                                             three_phase_audit_spec)
from repro.core.graph import CSRGraph
from repro.core.improved_pagerank import coupon_pool_sizes
from repro.core.simple_pagerank import walks_per_node_for


@dataclasses.dataclass
class DirectedDistResult(ImprovedDistResult):
    """ImprovedDistResult + Section-5 telemetry."""

    uniform_budget: int = 0   # coupons per node (every node gets the same)
    dangling_nodes: int = 0   # out-degree-0 vertices (immediate reset)


def distributed_directed_pagerank(
    graph: CSRGraph,
    eps: float,
    walks_per_node: Optional[int] = None,
    key: Optional[jnp.ndarray] = None,
    *,
    mesh: Optional[Mesh] = None,
    lam: Optional[int] = None,
    eta: Optional[int] = None,
    eta_safety: float = 2.0,
    cap2: Optional[int] = None,
    route_cap2: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
) -> DirectedDistResult:
    """Run the Section-5 directed/LOCAL algorithm across all devices of
    `mesh` (default: all devices).

    `cap2`/`route_cap2` size only the naive-tail buffers; the aggregated
    phases size themselves. `checkpoint_dir`/`fail_at`/`checkpoint_every`/
    `max_restarts`/`resume` select the checkpoint-restart supervisor over
    the shared phase-machine (see `distributed_improved._run_three_phase`):
    recovery is bit-exact."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    key = key if key is not None else jax.random.PRNGKey(0)
    n = graph.n
    K = walks_per_node or walks_per_node_for(n, eps)
    log_n = math.log(max(n, 2))
    if lam is None:
        lam = max(1, int(math.ceil(math.sqrt(log_n / eps))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))
    eta, pool_np = coupon_pool_sizes(graph, eps, K, lam, eta=eta,
                                     eta_safety=eta_safety,
                                     degree_proportional=False, ell=ell)
    # the naive tail is per-walk: worst-case W buffer (see module docstring)
    if cap2 is None:
        cap2 = n * K + int(mesh.devices.size) * 64
    return _run_three_phase(
        graph, eps, K, key, mesh, pool_np=pool_np, eta=int(eta),
        lam=int(lam), ell=int(ell), cap2=cap2, route_cap2=route_cap2,
        max_rounds=max_rounds, bandwidth_bits=bandwidth_bits,
        use_pallas=use_pallas, checkpoint_dir=checkpoint_dir,
        fail_at=fail_at, checkpoint_every=checkpoint_every,
        max_restarts=max_restarts, resume=resume,
        result_cls=DirectedDistResult,
        uniform_budget=int(pool_np[0]),
        dangling_nodes=int((np.asarray(graph.out_deg) == 0).sum()))


def audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float = 0.2,
               walks_per_node: int = 2, use_pallas: bool = False,
               bucketed: bool = True):
    """Section-5 frontend of the 3-phase audit spec: identical supersteps,
    uniform (LOCAL-model) coupon pools and the longer Section-5 lam —
    mirrors `distributed_directed_pagerank`'s sizing exactly."""
    n = graph.n
    K = walks_per_node
    log_n = math.log(max(n, 2))
    lam = max(1, int(math.ceil(math.sqrt(log_n / eps))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))
    _, pool_np = coupon_pool_sizes(graph, eps, K, lam,
                                   degree_proportional=False, ell=ell)
    return three_phase_audit_spec(graph, mesh, eps=eps, K=K,
                                  pool_np=pool_np, lam=lam,
                                  engine="directed",
                                  use_pallas=use_pallas, bucketed=bucketed)
