"""Multi-device Section-5 engine — directed graphs in the LOCAL model.

This fills the last cell of the ROADMAP engine matrix: the shard_map
realization of the paper's Section-5 extension of IMPROVED-PAGERANK to
directed graphs. It shares the entire 3-phase machinery with the
Algorithm-2 engine (`distributed_improved._run_three_phase`, built on the
lane/route/merge/exchange primitives in `routing.py`); what Section 5
changes is the *budget policy* and the *round budget*, not the supersteps:

  Uniform coupon budgets. On a directed graph there is no Lemma-2 bound
    relating walk visits to d(v) (short PageRank walks are not near
    degree-stationary), so Phase 1 cannot size vertex v's pool as
    d(v)*eta. Every node instead precomputes the same
    eta*ceil(log n) short walks (`coupon_pool_sizes(...,
    degree_proportional=False)`), the LOCAL-model analogue of the paper's
    "polynomially many coupons per node" — LOCAL rounds allow unbounded
    messages, so overprovisioning costs no rounds; our fixed-capacity
    buffers charge it to memory and all_to_all payload instead, which the
    telemetry reports.

  Longer short walks. With uniform budgets the optimal split of the
    length-ell long walk is lam = ceil(sqrt(log n / eps)) — the Section-5
    round bound O(sqrt(log n / eps)) — instead of the CONGEST
    lam = ceil(sqrt(log n)).

  Directed out-edges only, dangling resets. Walks move along the CSR
    out-edges exactly as written (nothing is symmetrized), and a walk
    arriving at a dangling node (out-degree 0) takes an immediate reset:
    `routing.advance_owned` terminates it on the spot, the same
    convention as `graph.transition_matrix` (dangling row = uniform
    teleport), so the estimator stays consistent with power iteration.

Phase structure, wire accounting, conservation counters (`dropped` must
stay 0), the exhaustion fallback to naive distributed walking, and the
psum-reduced estimator pi = zeta * eps/(nK) are identical to
`distributed_improved.py` — see that module for the superstep details.
Statistical target: `improved_pagerank.directed_local_pagerank` (the
single-device Section-5 engine) and power iteration on directed fixtures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.distributed import AXIS
from repro.core.distributed_improved import (ImprovedDistResult,
                                             _run_three_phase)
from repro.core.graph import CSRGraph
from repro.core.improved_pagerank import coupon_pool_sizes
from repro.core.simple_pagerank import walks_per_node_for


@dataclasses.dataclass
class DirectedDistResult(ImprovedDistResult):
    """ImprovedDistResult + Section-5 telemetry."""

    uniform_budget: int = 0   # coupons per node (every node gets the same)
    dangling_nodes: int = 0   # out-degree-0 vertices (immediate reset)


def distributed_directed_pagerank(
    graph: CSRGraph,
    eps: float,
    walks_per_node: Optional[int] = None,
    key: Optional[jnp.ndarray] = None,
    *,
    mesh: Optional[Mesh] = None,
    lam: Optional[int] = None,
    eta: Optional[int] = None,
    eta_safety: float = 2.0,
    cap1: Optional[int] = None,
    cap2: Optional[int] = None,
    route_cap1: Optional[int] = None,
    route_cap2: Optional[int] = None,
    rep_cap: Optional[int] = None,
    max_rounds: int = 100_000,
    bandwidth_bits: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    fail_at: Optional[Sequence[int]] = None,
    checkpoint_every: int = 10,
    max_restarts: int = 16,
    resume: bool = False,
) -> DirectedDistResult:
    """Run the Section-5 directed/LOCAL algorithm across all devices of
    `mesh` (default: all devices).

    `checkpoint_dir`/`fail_at`/`checkpoint_every`/`max_restarts`/`resume`
    select the checkpoint-restart supervisor over the shared phase-machine
    (see `distributed_improved._run_three_phase`): recovery is bit-exact."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    key = key if key is not None else jax.random.PRNGKey(0)
    n = graph.n
    K = walks_per_node or walks_per_node_for(n, eps)
    log_n = math.log(max(n, 2))
    if lam is None:
        lam = max(1, int(math.ceil(math.sqrt(log_n / eps))))
    ell = max(lam + 1, int(math.ceil(log_n / eps)))
    eta, pool_np = coupon_pool_sizes(graph, eps, K, lam, eta=eta,
                                     eta_safety=eta_safety,
                                     degree_proportional=False, ell=ell)
    # LOCAL-model buffer sizing: a directed hub can attract essentially the
    # whole coupon pool (resp. every long walk) in one round — there is no
    # Lemma-2 degree bound tying load to d(v), and the `distributed.py`
    # 2*W/P rule that serves the CONGEST engines overflows (drops) on
    # power-law webs. LOCAL charges unbounded per-round communication to
    # capacity instead of rounds, so default to worst-case buffers: every
    # coupon / walk co-resident on one shard.
    shards = int(mesh.devices.size)
    if cap1 is None:
        cap1 = int(pool_np.sum()) + shards * 64
    if cap2 is None:
        cap2 = n * K + shards * 64
    return _run_three_phase(
        graph, eps, K, key, mesh, pool_np=pool_np, eta=int(eta),
        lam=int(lam), ell=int(ell), cap1=cap1, cap2=cap2,
        route_cap1=route_cap1, route_cap2=route_cap2, rep_cap=rep_cap,
        max_rounds=max_rounds, bandwidth_bits=bandwidth_bits,
        checkpoint_dir=checkpoint_dir, fail_at=fail_at,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts,
        resume=resume, result_cls=DirectedDistResult,
        uniform_budget=int(pool_np[0]),
        dangling_nodes=int((np.asarray(graph.out_deg) == 0).sum()))
