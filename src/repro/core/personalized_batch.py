"""Batched multi-source Personalized PageRank — the query-serving engine.

Walk arrays carry a QUERY-ID LANE: every walk slot is a (position, qid)
pair, so ONE shard_map superstep advances every in-flight query at once.
Cross-shard movement rides the existing Lemma-1 count wire
(`routing.route_counts`) unchanged, over a *virtual* vertex space that
folds the query id into the vertex index:

    u = v * Q + q          owner(u) = u // (n_loc * Q) = v // n_loc

so the all_to_all payload per superstep is bounded by the number of
distinct (vertex, query) pairs with traffic — independent of how many
walks move — and the receiving shard re-materializes walks from the
delivered counts. That re-deal is sound because walks are anonymous
WITHIN a query: Lemma 1 of the paper, extended by one lane.

Hot paths reuse the seed kernels behind `use_pallas`: per-walk
advancement via `walk_step` (`routing.advance_owned`) and the
(vertex, query) aggregation / visit histograms via `histogram`
(`routing.vertex_histogram`).

The engine is RESIDENT: the sharded graph and the walk/visit buffers stay
on device across queries. `admit(slot, sources, ...)` installs a query
into a free slot (start walks + start visits, start counts drawn through
`personalized.source_start_counts` so the single-query engine and this
one share the same key-derived start distribution), `superstep()`
advances everything one round and reports per-query live-walk counts,
`extract(slot)` pulls one query's PPR vector. `serve/ppr_service.py`
layers continuous-batching admission, an LRU/TTL result cache, and
traffic stats on top; `batched_personalized_pagerank` below is the
one-shot batch driver used by the launch CLI and the conformance suite.

Buffer sizing: walks only terminate after admission, so a `cap` of
(num_slots * walks_per_query + slack) per shard can never overflow even
if every live walk lands on one shard — the default. Tighter caps trade
memory for a nonzero `dropped` risk; `dropped` must stay 0 for an exact
run (the serve bench gates on it).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import AXIS, ShardedGraph, shard_graph
from repro.core.graph import CSRGraph
from repro.core.personalized import (DEFAULT_MAX_ROUNDS, normalize_query,
                                     source_start_counts)
from repro.core.routing import (advance_owned, rank_within, route_counts,
                                count_owned_arrivals, shard_map,
                                vertex_histogram)
from repro.checkpoint import LayoutSpec, relayout_arrays
from repro.kernels import resolve_use_pallas


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BatchPPRState:
    pos: jnp.ndarray    # [P, cap] global padded vertex id, -1 = empty slot
    qid: jnp.ndarray    # [P, cap] query slot of each walk (0 where empty)
    zeta: jnp.ndarray   # [P, n_loc, Q] per-(owned vertex, query) visits
    key: jnp.ndarray    # [P, 2] per-shard PRNG keys


def ppr_state_specs(n: int, cap: int):
    """Elastic layout schema for the resident PPR engine's buffers —
    shared by `relayout_from` and the CONGEST auditor's schema lint."""
    return dict(
        pos=LayoutSpec(kind="walk", n=n, cap=cap, fill=-1, aux=("qid",)),
        qid=LayoutSpec(kind="walk_aux", fill=0),
        zeta=LayoutSpec(kind="vertex", n=n),
        key=LayoutSpec(kind="key"))


def _ppr_superstep(rp, ci, dg, pos, qid, zeta, key, *, eps: float,
                   n_loc: int, shards: int, Q: int, use_pallas: bool,
                   count_bound: Optional[int] = None):
    """One batched PPR round on a single shard (runs under shard_map).

    All buffered walks are owned by this shard by construction (arrivals
    are re-materialized owner-side), so every valid slot is eligible.
    """
    rp, ci, dg, pos, qid, zeta, key = (
        rp[0], ci[0], dg[0], pos[0], qid[0], zeta[0], key[0])
    shard_id = jax.lax.axis_index(AXIS)
    cap = pos.shape[0]
    key, k_term, k_edge = jax.random.split(key, 3)

    valid = pos >= 0
    survive, dst = advance_owned(rp, ci, dg, pos, valid, k_term, k_edge,
                                 eps, shard_id, n_loc,
                                 use_pallas=use_pallas)

    # Lemma-1 aggregation with a query lane: movers collapse to counts per
    # virtual (vertex, query) id and ride ONE route_counts exchange.
    u = dst * Q + qid
    per_virtual = vertex_histogram(u, survive, shards * n_loc * Q,
                                   use_pallas=use_pallas)
    arrivals, sent_entries, sent_bytes = route_counts(
        per_virtual, axis=AXIS, shard_id=shard_id, n_loc=n_loc * Q,
        shards=shards, use_pallas=use_pallas, count_bound=count_bound)

    # every arrival is a visit to an owned vertex
    zeta = zeta + arrivals.reshape(n_loc, Q)

    # re-deal the buffer from the arrival counts (anonymity within qid)
    cum = jnp.cumsum(arrivals)
    total = cum[-1]
    slot = jnp.arange(cap, dtype=jnp.int32)
    u_loc = jnp.minimum(
        jnp.searchsorted(cum, slot, side="right").astype(jnp.int32),
        n_loc * Q - 1)
    take = slot < total
    new_pos = jnp.where(take, shard_id * n_loc + u_loc // Q, -1)
    new_qid = jnp.where(take, u_loc % Q, 0)

    active_q = jax.lax.psum(
        jax.ops.segment_sum(take.astype(jnp.int32),
                            jnp.where(take, new_qid, Q),
                            num_segments=Q + 1)[:Q], AXIS)
    dropped = jax.lax.psum(jnp.maximum(total - cap, 0), AXIS)
    sent_entries = jax.lax.psum(sent_entries, AXIS)
    sent_bytes = jax.lax.psum(sent_bytes, AXIS)
    return (new_pos[None], new_qid[None], zeta[None], key[None],
            active_q, sent_entries, sent_bytes, dropped)


def _ppr_admit(pos, qid, zeta, starts, slot, *, n_loc: int, shards: int,
               Q: int, use_pallas: bool):
    """Install a query into slot `slot`: place its start walks into free
    buffer slots of the shards owning the start vertices, and reset the
    slot's visit column to the start visits (a start counts as a visit,
    matching `engine_walks.init_state`). Runs under shard_map; `starts`
    ([walks_per_query] global vertex ids) and `slot` are replicated."""
    pos, qid, zeta = pos[0], qid[0], zeta[0]
    shard_id = jax.lax.axis_index(AXIS)

    # defensive: a freed slot leaves no walks behind, but a re-admitted
    # slot must never inherit strays
    stale = (pos >= 0) & (qid == slot)
    pos = jnp.where(stale, -1, pos)

    mine = (starts >= 0) & (starts // n_loc == shard_id)
    zeta = zeta.at[:, slot].set(
        count_owned_arrivals(mine, starts, shard_id, n_loc,
                             use_pallas=use_pallas))

    # pack my starts into this shard's free buffer slots
    order = jnp.argsort(jnp.where(mine, 0, 1), stable=True)
    vals = starts[order]                       # first n_mine are mine
    n_mine = jnp.sum(mine)
    free_rank, _ = rank_within(jnp.where(pos < 0, 0, 1).astype(jnp.int32))
    take = (pos < 0) & (free_rank < n_mine)
    pick = vals[jnp.minimum(free_rank, starts.shape[0] - 1)]
    pos = jnp.where(take, pick, pos)
    qid = jnp.where(take, slot, qid)
    admit_dropped = jax.lax.psum(n_mine - jnp.sum(take), AXIS)
    return pos[None], qid[None], zeta[None], admit_dropped


class BatchedPPREngine:
    """Resident sharded graph + Q walk-slot batch of PPR queries.

    Telemetry (host counters, cumulative): `rounds`, `a2a_bytes`,
    `dropped` (buffer overflow — must stay 0), `admit_dropped` (admission
    overflow — must stay 0), `active` (the [Q] per-query live-walk counts
    after the last superstep).
    """

    def __init__(self, graph: CSRGraph, eps: float, *, num_slots: int,
                 walks_per_query: int, mesh: Optional[Mesh] = None,
                 cap: Optional[int] = None,
                 use_pallas: Optional[bool] = None):
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()), (AXIS,))
        self.graph = graph
        self.eps = float(eps)
        self.Q = int(num_slots)
        self.walks_per_query = int(walks_per_query)
        self.mesh = mesh
        self.shards = mesh.devices.size
        self.use_pallas = resolve_use_pallas(use_pallas)
        self.sg: ShardedGraph = shard_graph(graph, self.shards)
        if cap is None:
            # worst case: every live walk of every slot on one shard
            cap = self.Q * self.walks_per_query + 64
        self.cap = int(cap)

        spec = NamedSharding(mesh, P(AXIS))
        self._spec = spec
        self._rp = jax.device_put(self.sg.row_ptr, spec)
        self._ci = jax.device_put(self.sg.col_idx, spec)
        self._dg = jax.device_put(self.sg.out_deg, spec)

        n_loc = self.sg.n_loc
        step_sh = shard_map(
            partial(_ppr_superstep, eps=self.eps, n_loc=n_loc,
                    shards=self.shards, Q=self.Q,
                    use_pallas=self.use_pallas,
                    count_bound=self.walks_per_query),
            mesh,
            in_specs=(P(AXIS),) * 7,
            out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                       P(), P(), P(), P()))
        admit_sh = shard_map(
            partial(_ppr_admit, n_loc=n_loc, shards=self.shards, Q=self.Q,
                    use_pallas=self.use_pallas),
            mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P()),
            out_specs=(P(AXIS), P(AXIS), P(AXIS), P()))

        @jax.jit
        def _step(rp, ci, dg, st: BatchPPRState):
            pos, qid, zeta, key, active_q, entries, sent, dropped = step_sh(
                rp, ci, dg, st.pos, st.qid, st.zeta, st.key)
            return (BatchPPRState(pos=pos, qid=qid, zeta=zeta, key=key),
                    active_q, entries, sent, dropped)

        @jax.jit
        def _admit(st: BatchPPRState, starts, slot):
            pos, qid, zeta, admit_dropped = admit_sh(
                st.pos, st.qid, st.zeta, starts, slot)
            return (BatchPPRState(pos=pos, qid=qid, zeta=zeta, key=st.key),
                    admit_dropped)

        self._step = _step
        self._admit = _admit
        self.reset(jax.random.PRNGKey(0))

    # ------------------------------------------------------------ lifecycle
    def reset(self, key: jnp.ndarray) -> None:
        """Clear every slot and re-seed the per-shard PRNG streams."""
        spec = self._spec
        shape = (self.shards, self.cap)
        self.state = BatchPPRState(
            pos=jax.device_put(jnp.full(shape, -1, jnp.int32), spec),
            qid=jax.device_put(jnp.zeros(shape, jnp.int32), spec),
            zeta=jax.device_put(
                jnp.zeros((self.shards, self.sg.n_loc, self.Q), jnp.int32),
                spec),
            key=jax.device_put(jax.random.split(key, self.shards), spec))
        self.active = np.zeros(self.Q, dtype=np.int64)
        self.rounds = 0
        self.a2a_entries = 0
        self.a2a_bytes = 0
        self.dropped = 0
        self.admit_dropped = 0

    # ------------------------------------------------------------ admission
    def admit(self, slot: int, sources, weights=None,
              key: Optional[jnp.ndarray] = None) -> None:
        """Start `walks_per_query` walks from the query's source
        distribution in slot `slot` (which must be idle)."""
        if not 0 <= slot < self.Q:
            raise ValueError(f"slot {slot} out of range [0, {self.Q})")
        if self.active[slot] != 0:
            raise ValueError(f"slot {slot} still has live walks")
        key = key if key is not None else jax.random.PRNGKey(slot)
        sources, weights = normalize_query(sources, weights, self.graph.n)
        counts = source_start_counts(key, weights, self.walks_per_query)
        starts = jnp.asarray(np.repeat(sources, counts), dtype=jnp.int32)
        self.state, admit_dropped = self._admit(
            self.state, starts, jnp.int32(slot))
        self.admit_dropped += int(admit_dropped)
        self.active[slot] = self.walks_per_query - int(admit_dropped)

    # ------------------------------------------------------------- stepping
    def superstep(self) -> np.ndarray:
        """Advance every live walk of every query one round; returns the
        [Q] per-query live-walk counts (0 = query complete)."""
        self.state, active_q, entries, sent, dropped = self._step(
            self._rp, self._ci, self._dg, self.state)
        self.active = np.asarray(active_q, dtype=np.int64)
        self.rounds += 1
        self.a2a_entries += int(entries)
        self.a2a_bytes += int(sent)
        self.dropped += int(dropped)
        return self.active

    # ------------------------------------------------------------- elastic
    def relayout_from(self, other: "BatchedPPREngine") -> None:
        """Adopt `other`'s live serving state onto THIS engine's mesh.

        The walk buffer (with its query-id lane), the per-(vertex, query)
        visit shards, and the telemetry counters carry over through the
        schema-driven `checkpoint.relayout_arrays` — in-flight queries
        keep their walks and visit counts bit-for-bit (per-shard keys are
        re-derived, so the REMAINING steps of live walks are statistical,
        not a replay). Lets `serve.PPRService.resize` swap the resident
        engine onto a grown/shrunk mesh mid-traffic.
        """
        if (other.graph.n != self.graph.n or other.Q != self.Q
                or other.walks_per_query != self.walks_per_query):
            raise ValueError(
                f"engine mismatch: (n, Q, walks_per_query) "
                f"{(other.graph.n, other.Q, other.walks_per_query)} vs "
                f"{(self.graph.n, self.Q, self.walks_per_query)}")
        n = self.graph.n
        specs = ppr_state_specs(n, self.cap)
        arrays = {name: np.asarray(getattr(other.state, name))
                  for name in ("pos", "qid", "zeta", "key")}
        out = relayout_arrays(arrays, specs, other.shards, self.shards)
        self.cap = int(out["pos"].shape[1])    # auto-grown under walk skew
        spec = self._spec
        self.state = BatchPPRState(
            pos=jax.device_put(jnp.asarray(out["pos"]), spec),
            qid=jax.device_put(jnp.asarray(out["qid"]), spec),
            zeta=jax.device_put(jnp.asarray(out["zeta"]), spec),
            key=jax.device_put(jnp.asarray(out["key"]), spec))
        self.active = other.active.copy()
        self.rounds = other.rounds
        self.a2a_entries = other.a2a_entries
        self.a2a_bytes = other.a2a_bytes
        self.dropped = other.dropped
        self.admit_dropped = other.admit_dropped

    # -------------------------------------------------------------- results
    def extract(self, slot: int) -> np.ndarray:
        """The (unnormalized-estimator) PPR vector of slot `slot`:
        zeta * eps / walks_per_query, scaled in float64 on the host."""
        zeta = np.asarray(self.state.zeta[:, :, slot], dtype=np.int64)
        zeta = zeta.reshape(-1)[: self.graph.n]
        return zeta.astype(np.float64) * (self.eps / self.walks_per_query)


@dataclasses.dataclass
class BatchPPRResult:
    ppr: np.ndarray          # [num_queries, n] estimator vectors
    rounds: int
    a2a_bytes: int
    dropped: int             # walk-buffer overflow — 0 for an exact run
    admit_dropped: int       # admission overflow — 0 for an exact run
    shards: int
    active_trace: List[int]  # total live walks after each superstep
    a2a_entries: int = 0     # routed (virtual vertex, count) lane entries


def batched_personalized_pagerank(
        graph: CSRGraph, eps: float,
        queries: Sequence[Tuple[Sequence[int], Optional[Sequence[float]]]],
        walks_per_query: int, key: jnp.ndarray, *,
        mesh: Optional[Mesh] = None, cap: Optional[int] = None,
        use_pallas: Optional[bool] = None,
        max_rounds: int = DEFAULT_MAX_ROUNDS) -> BatchPPRResult:
    """One-shot batch driver: admit every query up front, run every walk
    to termination in shared supersteps, extract all results.

    `queries` is a sequence of (sources, weights-or-None). Query i's walk
    starts are derived from fold_in(key, i), so a batch is reproducible
    per key and each query resamples under a new key.
    """
    engine = BatchedPPREngine(graph, eps, num_slots=len(queries),
                              walks_per_query=walks_per_query, mesh=mesh,
                              cap=cap, use_pallas=use_pallas)
    engine.reset(jax.random.fold_in(key, 0xBA7C))
    for i, (sources, weights) in enumerate(queries):
        engine.admit(i, sources, weights, key=jax.random.fold_in(key, i))
    trace: List[int] = []
    while engine.active.sum() > 0 and engine.rounds < max_rounds:
        active = engine.superstep()
        trace.append(int(active.sum()))
    ppr = np.stack([engine.extract(i) for i in range(len(queries))])
    return BatchPPRResult(ppr=ppr, rounds=engine.rounds,
                          a2a_bytes=engine.a2a_bytes,
                          a2a_entries=engine.a2a_entries,
                          dropped=engine.dropped,
                          admit_dropped=engine.admit_dropped,
                          shards=engine.shards, active_trace=trace)


def audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float = 0.2,
               num_slots: int = 2, walks_per_query: int = 8,
               use_pallas: bool = False):
    """CONGEST-auditor spec for the batched PPR engine: the resident
    engine's jitted superstep (built with an auditor-pinned walk cap — the
    virtual-lane wire bound is independent of the buffer size), its
    declared (vertex, query)-lane budget, and the elastic schema."""
    from repro.core.accounting import (EngineAuditSpec, ExchangeSite,
                                       StageProgram)
    shards = int(mesh.devices.size)
    engine = BatchedPPREngine(graph, eps, num_slots=num_slots,
                              walks_per_query=walks_per_query, mesh=mesh,
                              cap=64, use_pallas=use_pallas)
    n_loc, Q, cap = engine.sg.n_loc, engine.Q, engine.cap
    sds = jax.ShapeDtypeStruct
    i32, u32 = jnp.int32, jnp.uint32
    sg = engine.sg
    state = BatchPPRState(pos=sds((shards, cap), i32),
                          qid=sds((shards, cap), i32),
                          zeta=sds((shards, n_loc, Q), i32),
                          key=sds((shards, 2), u32))
    args = (sds(sg.row_ptr.shape, sg.row_ptr.dtype),
            sds(sg.col_idx.shape, sg.col_idx.dtype),
            sds(sg.out_deg.shape, sg.out_deg.dtype), state)
    site = ExchangeSite(
        site="ppr", entry_nbytes=8, lane_entries=shards * n_loc * Q,
        budget_entries=shards * n_loc * Q,
        budget_formula=("P * n_loc * Q distinct (vertex, query) virtual "
                        "lanes — Lemma 1 extended by the query-id lane"),
        wire_class="count",
        note="bounded by distinct (vertex, query) pairs, never walk count")
    prog = StageProgram(stage="serve", program="superstep", fn=engine._step,
                        example_args=args, sites=(site,),
                        count_bound=walks_per_query)
    return EngineAuditSpec(
        engine="ppr", programs=[prog],
        stage_arrays={"serve": ("pos", "qid", "zeta", "key")},
        layouts={"serve": ppr_state_specs(graph.n, cap)},
        meta=dict(shards=shards, n=graph.n, Q=Q,
                  walks_per_query=walks_per_query))
