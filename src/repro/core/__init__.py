"""The paper's primary contribution: fast distributed PageRank computation.

Public API:
  CSRGraph, from_edges                    — graph substrate
  simple_pagerank (Algorithm 1)           — O(log n / eps) CONGEST rounds
  improved_pagerank (Algorithm 2)         — O(sqrt(log n) / eps) CONGEST rounds
  directed_local_pagerank (Section 5)     — O(sqrt(log n / eps)) LOCAL rounds
  power_iteration                         — classical baseline
  distributed_pagerank                    — shard_map multi-device engine
                                            (Algorithm 1, walk routing)
  distributed_pagerank_counts             — shard_map engine, Lemma-1
                                            count-aggregated wire
  distributed_improved_pagerank           — shard_map multi-device engine
                                            (Algorithm 2, three phases)
  distributed_directed_pagerank           — shard_map multi-device engine
                                            (Section 5 directed/LOCAL,
                                            uniform coupon budgets)

The distributed engines live in their own modules (not imported here) so
that `import repro.core` stays light for single-device workloads:
`repro.core.distributed`, `repro.core.distributed_counts`,
`repro.core.distributed_improved`, `repro.core.distributed_directed`,
with the shared lane/routing machinery in `repro.core.routing`.
"""
from repro.core.graph import CSRGraph, from_edges, exact_pagerank
from repro.core.power_iteration import power_iteration
from repro.core.simple_pagerank import (PageRankResult, simple_pagerank,
                                        walks_per_node_for)
from repro.core.improved_pagerank import (ImprovedResult, improved_pagerank,
                                          directed_local_pagerank)
from repro.core.personalized import exact_ppr, personalized_pagerank
from repro.core.estimator import (l1_error, linf_error, max_rel_error,
                                  normalized, pagerank_from_visits,
                                  topk_overlap)

__all__ = [
    "CSRGraph", "from_edges", "exact_pagerank", "power_iteration",
    "PageRankResult", "simple_pagerank", "walks_per_node_for",
    "ImprovedResult", "improved_pagerank", "directed_local_pagerank",
    "l1_error", "linf_error", "max_rel_error", "normalized",
    "pagerank_from_visits", "topk_overlap",
    "personalized_pagerank", "exact_ppr",
]
