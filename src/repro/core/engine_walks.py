"""Walk-array engine — the TPU-native realization of Algorithm 1.

A dense array of walk positions is advanced with vectorized gathers; visit
counters are one-hot-MXU histograms. Mathematically identical to the paper's
process (walks are iid PageRank random walks terminated at the first
eps-reset); the CONGEST message structure (per-edge *counts*, Lemma 1) is
recovered for accounting by histogramming the per-round edge transitions.

Two drivers:
  * run(...)        — jitted lax.while_loop to exact termination (fast path).
  * run_traced(...) — python-stepped, emits per-round RoundTrace for the
                      CONGEST accounting (benchmarks / theorem validation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import RoundTrace
from repro.core.graph import CSRGraph


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WalkState:
    pos: jnp.ndarray    # [W] int32 current vertex
    alive: jnp.ndarray  # [W] bool
    zeta: jnp.ndarray   # [n] int32 visit counters (includes start visits)
    key: jnp.ndarray    # PRNG key
    round: jnp.ndarray  # int32


def init_state(graph: CSRGraph, walks_per_node: int, key: jnp.ndarray,
               sources: Optional[jnp.ndarray] = None) -> WalkState:
    """K walks from every node (or explicit `sources`). Start counts as a visit."""
    if sources is None:
        pos = jnp.tile(jnp.arange(graph.n, dtype=jnp.int32), walks_per_node)
    else:
        pos = sources.astype(jnp.int32)
    zeta = jax.ops.segment_sum(jnp.ones_like(pos), pos, num_segments=graph.n)
    return WalkState(
        pos=pos,
        alive=jnp.ones(pos.shape, dtype=bool),
        zeta=zeta.astype(jnp.int32),
        key=key,
        round=jnp.int32(0),
    )


def _step_core(row_ptr, col_idx, out_deg, eps: float, state: WalkState,
               *, use_pallas: bool = False):
    """One synchronous round. Returns (new_state, moving_mask, edge_ids)."""
    key, k_term, k_edge = jax.random.split(state.key, 3)
    u_term = jax.random.uniform(k_term, state.pos.shape)
    deg = out_deg[state.pos]
    # dangling vertex == immediate reset (Avrachenkov convention)
    survive = state.alive & (u_term >= eps) & (deg > 0)
    u_edge = jax.random.uniform(k_edge, state.pos.shape)
    j = jnp.minimum((u_edge * jnp.maximum(deg, 1)).astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
    edge_ids = row_ptr[state.pos] + j
    dst = col_idx[jnp.clip(edge_ids, 0, col_idx.shape[0] - 1)]
    new_pos = jnp.where(survive, dst, state.pos)
    if use_pallas:
        from repro.kernels.histogram import ops as hist_ops

        arrivals = hist_ops.histogram(
            jnp.where(survive, dst, jnp.int32(-1)), state.zeta.shape[0])
    else:
        arrivals = jax.ops.segment_sum(
            survive.astype(jnp.int32), dst, num_segments=state.zeta.shape[0])
    new_state = WalkState(
        pos=new_pos,
        alive=survive,
        zeta=state.zeta + arrivals,
        key=key,
        round=state.round + 1,
    )
    return new_state, survive, edge_ids


@partial(jax.jit, static_argnames=("eps", "max_rounds", "use_pallas"))
def _run_while(row_ptr, col_idx, out_deg, state: WalkState, eps: float,
               max_rounds: int, use_pallas: bool) -> WalkState:
    def cond(s):
        return jnp.logical_and(jnp.any(s.alive), s.round < max_rounds)

    def body(s):
        s2, _, _ = _step_core(row_ptr, col_idx, out_deg, eps, s,
                              use_pallas=use_pallas)
        return s2

    return jax.lax.while_loop(cond, body, state)


def run(graph: CSRGraph, eps: float, walks_per_node: int, key: jnp.ndarray,
        *, max_rounds: int = 100_000, use_pallas: bool = False) -> WalkState:
    state = init_state(graph, walks_per_node, key)
    return _run_while(graph.row_ptr, graph.col_idx, graph.out_deg, state,
                      float(eps), int(max_rounds), bool(use_pallas))


@partial(jax.jit, static_argnames=("eps", "n_edges", "use_pallas"))
def _step_traced(row_ptr, col_idx, out_deg, state: WalkState, eps: float,
                 n_edges: int, use_pallas: bool):
    new_state, survive, edge_ids = _step_core(
        row_ptr, col_idx, out_deg, eps, state, use_pallas=use_pallas)
    # CONGEST payload: count of walks per edge this round (Lemma 1 messages)
    edge_counts = jax.ops.segment_sum(
        survive.astype(jnp.int32), edge_ids, num_segments=n_edges)
    stats = dict(
        active=jnp.sum(state.alive).astype(jnp.int32),
        moved=jnp.sum(survive).astype(jnp.int32),
        messages=jnp.sum(edge_counts > 0).astype(jnp.int32),
        max_edge_count=jnp.max(edge_counts).astype(jnp.int32),
    )
    return new_state, stats


def run_traced(graph: CSRGraph, eps: float, walks_per_node: int,
               key: jnp.ndarray, *, max_rounds: int = 100_000,
               use_pallas: bool = False) -> Tuple[WalkState, List[RoundTrace]]:
    state = init_state(graph, walks_per_node, key)
    traces: List[RoundTrace] = []
    while bool(jnp.any(state.alive)) and int(state.round) < max_rounds:
        state, stats = _step_traced(graph.row_ptr, graph.col_idx,
                                    graph.out_deg, state, float(eps),
                                    graph.m, bool(use_pallas))
        traces.append(RoundTrace(
            active_walks=int(stats["active"]),
            messages=int(stats["messages"]),
            max_edge_count=int(stats["max_edge_count"]),
            total_count=int(stats["moved"]),
        ))
    return state, traces
