"""Multi-device distributed PageRank engine (shard_map).

This is the TPU-pod realization of the paper's CONGEST network: vertices are
partitioned into contiguous shards, one per mesh device; a logical round is a
bulk-synchronous super-step:

    route  — walks whose current vertex is owned by another shard are
             exchanged with a fixed-capacity `all_to_all` (the payload is the
             paper's Lemma-1 insight: anonymous walk positions/counts, never
             identities);
    step   — each shard advances its owned walks one PageRank step
             (terminate w.p. eps, else uniform out-edge).

Static shapes throughout: per-shard walk buffers of capacity `cap`, per
(shard,shard) routing lanes of capacity `route_cap`. Walks that do not fit a
routing lane in a round *wait* (correctness preserved — a waiting walk is
simply delayed) and are carried over; a `work_cap` bound on steps per shard
per round provides straggler mitigation (uniform round time). Buffer
overflow beyond `cap` is counted in `dropped` and must be 0 for an exact
run — the sizing rule `cap >= 2*W/P + P*route_cap` keeps it 0 in practice.

Visit counting: a walk's arrival is counted by the *owner* shard exactly
once — immediately for intra-shard moves, at receive time for routed walks.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache, partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.estimator import pagerank_from_visits
from repro.core.graph import CSRGraph
from repro.core.routing import (advance_owned, count_owned_arrivals,
                                merge_walks, rank_within, route_walks,
                                shard_map)
from repro.kernels import resolve_use_pallas

AXIS = "shards"


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Vertex-partitioned CSR: shard p owns [p*n_loc, (p+1)*n_loc)."""

    n: int
    n_pad: int
    n_loc: int
    shards: int
    row_ptr: jnp.ndarray   # [P, n_loc+1] rebased per shard
    col_idx: jnp.ndarray   # [P, m_loc_pad] global vertex ids
    out_deg: jnp.ndarray   # [P, n_loc]


def shard_graph(graph: CSRGraph, shards: int) -> ShardedGraph:
    n_loc = math.ceil(graph.n / shards)
    n_pad = n_loc * shards
    row_ptr = np.asarray(graph.row_ptr)
    col = np.asarray(graph.col_idx)
    deg = np.concatenate([np.asarray(graph.out_deg),
                          np.zeros(n_pad - graph.n, dtype=np.int32)])
    m_loc = []
    for p in range(shards):
        lo = min(p * n_loc, graph.n)
        hi = min((p + 1) * n_loc, graph.n)
        m_loc.append(int(row_ptr[hi] - row_ptr[lo]))
    m_pad = max(max(m_loc), 1)
    rp = np.zeros((shards, n_loc + 1), dtype=np.int32)
    ci = np.zeros((shards, m_pad), dtype=np.int32)
    dg = np.zeros((shards, n_loc), dtype=np.int32)
    for p in range(shards):
        lo = min(p * n_loc, graph.n)
        hi = min((p + 1) * n_loc, graph.n)
        local_rp = row_ptr[lo:hi + 1] - row_ptr[lo]
        rp[p, : hi - lo + 1] = local_rp
        rp[p, hi - lo + 1:] = local_rp[-1]
        ci[p, : m_loc[p]] = col[row_ptr[lo]:row_ptr[hi]]
        dg[p, : hi - lo] = deg[lo:hi]
    return ShardedGraph(n=graph.n, n_pad=n_pad, n_loc=n_loc, shards=shards,
                        row_ptr=jnp.asarray(rp), col_idx=jnp.asarray(ci),
                        out_deg=jnp.asarray(dg))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistState:
    pos: jnp.ndarray     # [P, cap] global vertex id, -1 = empty slot
    zeta: jnp.ndarray    # [P, n_loc]
    key: jnp.ndarray     # [P, 2] per-shard PRNG keys (uint32)
    round: jnp.ndarray   # [] int32
    dropped: jnp.ndarray  # [] int32 — must stay 0 for an exact run
    waited: jnp.ndarray   # [] int32 — routing-lane carry-overs (stat)


def _superstep_local(rp, ci, dg, pos, key, zeta, eps: float, n_loc: int,
                     shards: int, route_cap: int, work_cap: int,
                     use_pallas: bool = False):
    """One super-step on a single shard (runs under shard_map).

    Inputs arrive with a leading size-1 shard dim (shard_map blocks);
    squeeze on entry, re-expand on exit.
    """
    rp, ci, dg, pos, key, zeta = (rp[0], ci[0], dg[0], pos[0], key[0], zeta[0])
    shard_id = jax.lax.axis_index(AXIS)

    # ---- route: send non-owned walks, up to route_cap per target ----
    kept, _, recv, _, waited, sent_entries, sent_bytes = route_walks(
        pos, {}, axis=AXIS, shard_id=shard_id, n_loc=n_loc, shards=shards,
        route_cap=route_cap)
    arrived = recv >= 0
    # count arrivals (they are owned by me by construction)
    zeta = zeta + count_owned_arrivals(arrived, recv, shard_id, n_loc,
                                       use_pallas=use_pallas)

    # ---- merge buffer: kept walks + arrivals, compact into cap slots ----
    pos, _, dropped = merge_walks(kept, {}, recv, {}, pos.shape[0])

    # ---- step: advance owned walks (straggler-bounded) ----
    key, k_term, k_edge = jax.random.split(key, 3)
    valid = pos >= 0
    owner = jnp.where(valid, pos // n_loc, shards)
    owned = valid & (owner == shard_id)
    owned_rank, _ = rank_within(jnp.where(owned, 0, 1).astype(jnp.int32))
    stepped = owned & (owned_rank < work_cap) if work_cap else owned
    survive, dst = advance_owned(rp, ci, dg, pos, stepped, k_term, k_edge,
                                 eps, shard_id, n_loc,
                                 use_pallas=use_pallas)
    new_pos = jnp.where(survive, dst, jnp.where(stepped, -1, pos))
    # intra-shard arrivals counted immediately
    local_arrival = survive & (dst // n_loc == shard_id)
    zeta = zeta + count_owned_arrivals(local_arrival, dst, shard_id, n_loc,
                                       use_pallas=use_pallas)

    # global (replicated) scalar stats
    active = jax.lax.psum(jnp.sum(new_pos >= 0), AXIS)
    dropped = jax.lax.psum(dropped, AXIS)
    waited = jax.lax.psum(waited, AXIS)
    a2a_entries = jax.lax.psum(sent_entries, AXIS)
    a2a_bytes = jax.lax.psum(sent_bytes, AXIS)
    return (new_pos[None], key[None], zeta[None],
            active, dropped, waited, a2a_entries, a2a_bytes)


# memoized: equal (mesh, config) arguments produce byte-identical jitted
# programs, and a fresh closure per engine call would recompile the
# superstep on every invocation (jax interns Mesh, so the cache hits even
# when callers rebuild the mesh over the same devices)
@lru_cache(maxsize=64)
def _make_superstep(mesh: Mesh, eps: float, n_loc: int, shards: int,
                    route_cap: int, work_cap: int,
                    use_pallas: bool = False):
    fn = partial(_superstep_local, eps=eps, n_loc=n_loc, shards=shards,
                 route_cap=route_cap, work_cap=work_cap,
                 use_pallas=use_pallas)
    sharded = shard_map(
        fn, mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(), P(), P()),
    )

    @jax.jit
    def step(sg_row_ptr, sg_col, sg_deg, state: DistState):
        new_pos, key, zeta, active, dropped, waited, entries, a2a = sharded(
            sg_row_ptr, sg_col, sg_deg, state.pos, state.key, state.zeta)
        return DistState(pos=new_pos, zeta=zeta, key=key,
                         round=state.round + 1,
                         dropped=state.dropped + dropped,
                         waited=state.waited + waited), active, entries, a2a

    return step


@dataclasses.dataclass
class DistributedResult:
    zeta: jnp.ndarray          # [n] global visit counts
    pi: jnp.ndarray
    rounds: int
    dropped: int
    waited: int
    a2a_entries_total: int   # routed lane entries (4 B each, int32 pos)
    a2a_bytes_total: int
    shards: int
    # per-round telemetry: walks alive after each super-step (walks only
    # terminate, so this must be non-increasing for a conserving run)
    round_active: List[int] = dataclasses.field(default_factory=list)


def distributed_pagerank(
    graph: CSRGraph,
    eps: float,
    walks_per_node: int,
    key: jnp.ndarray,
    *,
    mesh: Optional[Mesh] = None,
    cap: Optional[int] = None,
    route_cap: Optional[int] = None,
    work_cap: int = 0,
    max_rounds: int = 100_000,
    use_pallas: Optional[bool] = None,
) -> DistributedResult:
    """Run Algorithm 1 across all devices of `mesh` (default: all devices).

    `use_pallas=None` defers to the REPRO_USE_PALLAS env var; True routes
    the per-shard walk advancement and visit histograms through the Pallas
    kernels (bit-identical to the jnp path, interpret mode off-TPU)."""
    use_pallas = resolve_use_pallas(use_pallas)
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (AXIS,))
    shards = mesh.devices.size
    sg = shard_graph(graph, shards)
    W = graph.n * walks_per_node
    if cap is None:
        cap = max(2 * W // shards + shards * 64, 256)
    if route_cap is None:
        route_cap = max(W // shards, 64)

    # init: walks start at their own vertex; zeta starts at K per real vertex
    pos0 = np.full((shards, cap), -1, dtype=np.int32)
    zeta0 = np.zeros((shards, sg.n_loc), dtype=np.int32)
    for p in range(shards):
        lo = min(p * sg.n_loc, graph.n)
        hi = min((p + 1) * sg.n_loc, graph.n)
        locs = np.repeat(np.arange(lo, hi, dtype=np.int32), walks_per_node)
        assert len(locs) <= cap, "cap too small for initial placement"
        pos0[p, : len(locs)] = locs
        zeta0[p, : hi - lo] = walks_per_node

    keys = jax.random.split(key, shards)
    spec = NamedSharding(mesh, P(AXIS))
    state = DistState(
        pos=jax.device_put(jnp.asarray(pos0), spec),
        zeta=jax.device_put(jnp.asarray(zeta0), spec),
        key=jax.device_put(keys, spec),
        round=jnp.int32(0),
        dropped=jnp.int32(0),
        waited=jnp.int32(0),
    )
    sg_rp = jax.device_put(sg.row_ptr, spec)
    sg_ci = jax.device_put(sg.col_idx, spec)
    sg_dg = jax.device_put(sg.out_deg, spec)

    step = _make_superstep(mesh, float(eps), sg.n_loc, shards,
                           int(route_cap), int(work_cap),
                           use_pallas=use_pallas)
    a2a_total = 0
    entries_total = 0
    rounds = 0
    round_active: List[int] = []
    while rounds < max_rounds:
        state, active, entries, a2a = step(sg_rp, sg_ci, sg_dg, state)
        a2a_total += int(a2a)
        entries_total += int(entries)
        rounds += 1
        round_active.append(int(active))
        if int(active) == 0:
            break
    zeta = state.zeta.reshape(-1)[: graph.n]
    pi = pagerank_from_visits(zeta, graph.n, walks_per_node, eps)
    return DistributedResult(
        zeta=zeta, pi=pi, rounds=rounds, dropped=int(state.dropped),
        waited=int(state.waited), a2a_entries_total=entries_total,
        a2a_bytes_total=a2a_total, shards=shards,
        round_active=round_active)


# --------------------------------------------------------------------------
# checkpoint/restart hooks (used by runtime.fault_tolerance)
# --------------------------------------------------------------------------

def state_to_host(state: DistState) -> dict:
    return dict(pos=np.asarray(state.pos), zeta=np.asarray(state.zeta),
                key=np.asarray(state.key), round=int(state.round),
                dropped=int(state.dropped), waited=int(state.waited))


def state_from_host(d: dict, mesh: Mesh) -> DistState:
    spec = NamedSharding(mesh, P(AXIS))
    return DistState(
        pos=jax.device_put(jnp.asarray(d["pos"]), spec),
        zeta=jax.device_put(jnp.asarray(d["zeta"]), spec),
        key=jax.device_put(jnp.asarray(d["key"]), spec),
        round=jnp.int32(d["round"]),
        dropped=jnp.int32(d["dropped"]),
        waited=jnp.int32(d["waited"]),
    )


# --------------------------------------------------------------------------
# static wire-budget declaration (consumed by `analysis.congest`)
# --------------------------------------------------------------------------

def audit_spec(graph: CSRGraph, mesh: Mesh, *, eps: float = 0.2,
               walks_per_node: int = 2, work_cap: int = 0,
               use_pallas: bool = False):
    """The walk engine's `EngineAuditSpec` for the CONGEST auditor.

    The runtime `route_cap` scales with W/P, so this engine's lanes are
    walk-class wire: the auditor traces with `route_cap` PINNED at n_loc
    (legal — overflowing walks wait and retry, any cap is correct), which
    makes the checked capacity a W-free function of the partition. The
    walk-buffer `cap` never touches the wire and is pinned too.
    """
    from repro.checkpoint import pagerank_state_specs
    from repro.core.accounting import (EngineAuditSpec, ExchangeSite,
                                       StageProgram)
    shards = int(mesh.devices.size)
    sg = shard_graph(graph, shards)
    n_loc = sg.n_loc
    route_cap = n_loc
    cap = n_loc
    step = _make_superstep(mesh, float(eps), n_loc, shards, route_cap,
                           int(work_cap), use_pallas=use_pallas)
    sds = jax.ShapeDtypeStruct
    i32, u32 = jnp.int32, jnp.uint32
    state = DistState(pos=sds((shards, cap), i32),
                      zeta=sds((shards, n_loc), i32),
                      key=sds((shards, 2), u32),
                      round=sds((), i32), dropped=sds((), i32),
                      waited=sds((), i32))
    args = (sds((shards, n_loc + 1), i32),
            sds((shards, sg.col_idx.shape[1]), i32),
            sds((shards, n_loc), i32), state)
    site = ExchangeSite(
        site="route", entry_nbytes=4, lane_entries=shards * route_cap,
        budget_entries=shards * n_loc,
        budget_formula="P * n_loc lane slots (auditor-pinned "
                       "route_cap = n_loc)",
        wire_class="walk",
        note="runtime route_cap scales with W/P; overflow waits rather "
             "than widening the lane, so any pinned cap is correct")
    prog = StageProgram(stage="walks", program="step", fn=step,
                        example_args=args, sites=(site,),
                        count_bound=graph.n * walks_per_node)
    return EngineAuditSpec(
        engine="walks", programs=[prog],
        stage_arrays={"walks": ("pos", "zeta", "key", "round", "dropped",
                                "waited")},
        layouts={"walks": pagerank_state_specs(graph.n, cap=cap)},
        meta=dict(shards=shards, n=graph.n, walks_per_node=walks_per_node))
