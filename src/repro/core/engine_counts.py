"""Count-based engine — the *faithful* Algorithm 1 implementation.

This engine materializes exactly the paper's CONGEST messages: per round,
every vertex v holding c_v coupons draws terminations ~ Binomial(c_v, eps)
and splits the survivors across its out-edges with a Multinomial (sampled as
the conditional-binomial chain, vectorized over all vertices). The int
matrix T[v, j] of per-edge counts *is* the message set of the round
(Lemma 1: counts, never identities).

Slower than the walk-array engine (O(max_deg) binomial draws per round) but
byte-for-byte faithful to the pseudocode — it is the reference for message
accounting and for the engine-equivalence tests.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.accounting import RoundTrace
from repro.core.graph import CSRGraph, padded_adjacency


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CountState:
    counts: jnp.ndarray  # [n] int32 coupons currently at each vertex
    zeta: jnp.ndarray    # [n] int32 visit counters
    key: jnp.ndarray
    round: jnp.ndarray


def init_state(graph: CSRGraph, walks_per_node: int, key: jnp.ndarray) -> CountState:
    c0 = jnp.full((graph.n,), walks_per_node, dtype=jnp.int32)
    return CountState(counts=c0, zeta=c0, key=key, round=jnp.int32(0))


def _multinomial_split(key, survivors, deg, max_deg: int):
    """T[v, j] ~ Multinomial(survivors_v, uniform over deg_v slots).

    Conditional-binomial chain: T_j | T_<j ~ Bin(rem, 1/(deg-j)).
    """
    def body(carry, j):
        rem, key = carry
        key, kb = jax.random.split(key)
        slots_left = jnp.maximum(deg - j, 1).astype(jnp.float32)
        p = jnp.where(j < deg, 1.0 / slots_left, 0.0)
        t = jax.random.binomial(kb, rem.astype(jnp.float32), p).astype(jnp.int32)
        t = jnp.minimum(t, rem)
        return (rem - t, key), t

    (rem, _), T = jax.lax.scan(body, (survivors, key), jnp.arange(max_deg))
    # scan stacks on axis 0 -> [max_deg, n]; transpose to [n, max_deg]
    return T.T, rem


@partial(jax.jit, static_argnames=("eps", "n", "max_deg"))
def _step(nbr, deg, state: CountState, eps: float, n: int, max_deg: int):
    key, k_term, k_split = jax.random.split(state.key, 3)
    # terminations: each coupon independently resets w.p. eps
    term = jax.random.binomial(
        k_term, state.counts.astype(jnp.float32), eps).astype(jnp.int32)
    survivors = state.counts - term
    # dangling vertices: every coupon terminates (reset) — no out-edge
    survivors = jnp.where(deg > 0, survivors, 0)
    T, rem = _multinomial_split(k_split, survivors, deg, max_deg)
    # route: new_counts[u] = sum over (v, j) with nbr[v,j] == u of T[v,j]
    flat_dst = nbr.reshape(-1)
    flat_T = T.reshape(-1)
    new_counts = jax.ops.segment_sum(flat_T, flat_dst, num_segments=n)
    new_state = CountState(
        counts=new_counts.astype(jnp.int32),
        zeta=state.zeta + new_counts.astype(jnp.int32),
        key=key,
        round=state.round + 1,
    )
    stats = dict(
        active=jnp.sum(state.counts),
        moved=jnp.sum(T),
        messages=jnp.sum(T > 0),
        max_edge_count=jnp.max(T),
        residual=jnp.sum(rem),  # must be 0 — multinomial exactness check
    )
    return new_state, stats


def run_traced(graph: CSRGraph, eps: float, walks_per_node: int,
               key: jnp.ndarray, *, max_rounds: int = 100_000
               ) -> Tuple[CountState, List[RoundTrace]]:
    nbr, _ = padded_adjacency(graph)
    max_deg = int(nbr.shape[1])
    state = init_state(graph, walks_per_node, key)
    traces: List[RoundTrace] = []
    while int(jnp.sum(state.counts)) > 0 and int(state.round) < max_rounds:
        state, stats = _step(nbr, graph.out_deg, state, float(eps), graph.n, max_deg)
        assert int(stats["residual"]) == 0, "multinomial split leaked mass"
        traces.append(RoundTrace(
            active_walks=int(stats["active"]),
            messages=int(stats["messages"]),
            max_edge_count=int(stats["max_edge_count"]),
            total_count=int(stats["moved"]),
        ))
    return state, traces
