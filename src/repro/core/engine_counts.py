"""Count-based engine — the *faithful* Algorithm 1 implementation.

This engine materializes exactly the paper's CONGEST messages: per round,
every vertex v holding c_v coupons draws terminations ~ Binomial(c_v, eps)
and splits the survivors across its out-edges with a Multinomial (sampled as
the conditional-binomial chain, vectorized over all vertices). The int
matrix T[v, j] of per-edge counts *is* the message set of the round
(Lemma 1: counts, never identities).

Slower than the walk-array engine but byte-for-byte faithful to the
pseudocode — it is the reference for message accounting and for the
engine-equivalence tests. The per-round splits run through the shared
degree-bucketed aggregate sampler (`core/aggregate_sampler`): the
conditional-binomial chain scans each row's power-of-two bucket width
instead of the global max degree, so per-round sampler FLOPs are
sum_v O(deg(v)) — hubs no longer tax every low-degree vertex.
`use_pallas` routes the draws through the `kernels/multinomial_rows`
Pallas kernel (same counter-RNG math as the jnp ref, so results are
bit-identical either way); `bucketed=False` keeps the single-bucket
max_deg-wide layout for benchmarking the pre-bucketing shape.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import RoundTrace
from repro.core.aggregate_sampler import (build_layout, bucketize_adjacency,
                                          flatten_moves, sample_buckets)
from repro.core.graph import CSRGraph, padded_adjacency
from repro.kernels import resolve_use_pallas
from repro.kernels.multinomial_rows._math import key_words


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CountState:
    counts: jnp.ndarray  # [n] int32 coupons currently at each vertex
    zeta: jnp.ndarray    # [n] int32 visit counters
    key: jnp.ndarray
    round: jnp.ndarray


def init_state(graph: CSRGraph, walks_per_node: int, key: jnp.ndarray) -> CountState:
    c0 = jnp.full((graph.n,), walks_per_node, dtype=jnp.int32)
    return CountState(counts=c0, zeta=c0, key=key, round=jnp.int32(0))


def _multinomial_split(key, survivors, deg, max_deg: int):
    """T[v, j] ~ Multinomial(survivors_v, uniform over deg_v slots).

    Conditional-binomial chain: T_j | T_<j ~ Bin(rem, 1/(deg-j)).
    """
    def body(carry, j):
        rem, key = carry
        key, kb = jax.random.split(key)
        slots_left = jnp.maximum(deg - j, 1).astype(jnp.float32)
        p = jnp.where(j < deg, 1.0 / slots_left, 0.0)
        t = jax.random.binomial(kb, rem.astype(jnp.float32), p).astype(jnp.int32)
        t = jnp.minimum(t, rem)
        return (rem - t, key), t

    (rem, _), T = jax.lax.scan(body, (survivors, key), jnp.arange(max_deg))
    # scan stacks on axis 0 -> [max_deg, n]; transpose to [n, max_deg]
    return T.T, rem


@partial(jax.jit, static_argnames=("eps", "n", "layout", "use_pallas"))
def _step(bnbr, perm, deg, state: CountState, eps: float, n: int, layout,
          use_pallas: bool):
    """One super-step through the shared degree-bucketed sampler: each
    bucket draws its fused Binomial(eps) termination + conditional-binomial
    edge split (dangling rows terminate whole), then the per-edge counts
    route through one segment-sum over the flat bucketed adjacency."""
    key, k_sample = jax.random.split(state.key)
    rid = jnp.arange(n, dtype=jnp.int32)
    samples, _, residual = sample_buckets(
        state.counts, deg, rid, key_words(k_sample), perm, layout,
        eps=eps, use_pallas=use_pallas)
    flat_T = flatten_moves(samples)
    # route: new_counts[u] = sum over bucketed edge slots with dst == u
    new_counts = jax.ops.segment_sum(flat_T, bnbr, num_segments=n)
    new_state = CountState(
        counts=new_counts.astype(jnp.int32),
        zeta=state.zeta + new_counts.astype(jnp.int32),
        key=key,
        round=state.round + 1,
    )
    stats = dict(
        active=jnp.sum(state.counts),
        moved=jnp.sum(flat_T),
        messages=jnp.sum(flat_T > 0),
        max_edge_count=jnp.max(flat_T),
        residual=residual,  # must be 0 — multinomial exactness check
    )
    return new_state, stats


def run_traced(graph: CSRGraph, eps: float, walks_per_node: int,
               key: jnp.ndarray, *, max_rounds: int = 100_000,
               use_pallas=None, bucketed: bool = True
               ) -> Tuple[CountState, List[RoundTrace]]:
    use_pallas = resolve_use_pallas(use_pallas)
    nbr, _ = padded_adjacency(graph)
    max_deg = int(nbr.shape[1])
    layout, perm_np = build_layout(np.asarray(graph.out_deg), max_deg,
                                   bucketed=bucketed)
    bnbr = jnp.asarray(bucketize_adjacency(np.asarray(nbr), perm_np, layout))
    perm = jnp.asarray(perm_np)
    state = init_state(graph, walks_per_node, key)
    traces: List[RoundTrace] = []
    while int(jnp.sum(state.counts)) > 0 and int(state.round) < max_rounds:
        state, stats = _step(bnbr, perm, graph.out_deg, state, float(eps),
                             graph.n, layout, use_pallas)
        assert int(stats["residual"]) == 0, "multinomial split leaked mass"
        traces.append(RoundTrace(
            active_walks=int(stats["active"]),
            messages=int(stats["messages"]),
            max_edge_count=int(stats["max_edge_count"]),
            total_count=int(stats["moved"]),
        ))
    return state, traces
