from repro.data.pipeline import (DataConfig, PageRankWeightedSampler,
                                 SyntheticTokens)

__all__ = ["DataConfig", "PageRankWeightedSampler", "SyntheticTokens"]
