"""Deterministic sharded synthetic token pipeline + PageRank-weighted
document sampling.

The pipeline is the framework's data substrate: host-side, deterministic
per (seed, shard, step) — any worker can reproduce any batch, which is what
makes checkpoint-restart and elastic rescale exact (no data-order drift).

`PageRankWeightedSampler` is the paper-integration point: documents live in
a link graph; the distributed PageRank engine (core/) scores them; sampling
probabilities follow the scores (classic web-corpus curation). See
examples/pagerank_data_weighting.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0


class SyntheticTokens:
    """Markov-ish synthetic stream: deterministic per (seed, shard, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.num_shards == 0
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard_id)
        toks = rng.integers(0, cfg.vocab_size,
                            size=(self.local_batch, cfg.seq_len + 1),
                            dtype=np.int64).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PageRankWeightedSampler:
    """Sample document ids proportionally to PageRank scores and emit
    doc-conditioned token sequences (each doc has a stable token 'style')."""

    def __init__(self, scores: np.ndarray, cfg: DataConfig):
        scores = np.asarray(scores, dtype=np.float64)
        scores = np.maximum(scores, 0)
        self.p = scores / scores.sum()
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 998_244_353 + step) * 257 + cfg.shard_id)
        docs = rng.choice(len(self.p), size=self.local_batch, p=self.p)
        toks = np.empty((self.local_batch, cfg.seq_len + 1), dtype=np.int32)
        for i, d in enumerate(docs):
            doc_rng = np.random.default_rng(int(d) * 31 + cfg.seed)
            base = doc_rng.integers(0, cfg.vocab_size, size=cfg.seq_len + 1)
            noise = rng.integers(0, cfg.vocab_size, size=cfg.seq_len + 1)
            mix = rng.random(cfg.seq_len + 1) < 0.1
            toks[i] = np.where(mix, noise, base).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:],
                    doc_ids=docs.astype(np.int32))

    def empirical_doc_freq(self, steps: int = 50) -> np.ndarray:
        counts = np.zeros(len(self.p))
        for s in range(steps):
            b = self.batch_at(s)
            np.add.at(counts, b["doc_ids"], 1)
        return counts / counts.sum()
