"""Continuous batching simulator for serving.

A fixed number of decode slots; requests (prompt + max_new_tokens) are
admitted as slots free up, prefilled individually into their slot's cache
region, and all active slots advance together through `decode_step`.
This is the scheduling layer a real serving deployment runs per model
replica; here it drives any registry model at reduced scale and is
exercised end-to-end in examples/serve_lm.py.

Implementation notes: per-slot caches are a batch dim of the stacked model
cache; admission writes a fresh prefill cache into the slot (tree-indexed
dynamic updates); completed slots are freed when EOS or the token budget
hits. Batch-1 prefill per admission keeps the compiled-step count at two
(one prefill, one decode) regardless of traffic.

Accounting is EXACT: the completion check runs after every token append —
the prefill-argmax token at admission included — so a request emits
precisely max_new_tokens tokens (a max_new_tokens=1 request completes at
admission and never holds a decode slot), `stats.tokens_out` counts every
emitted token, and `stats.steps`/`stats.max_active` reflect only decode
batches that actually ran.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    prefills: int = 0
    tokens_out: int = 0
    completed: int = 0
    max_active: int = 0


class ContinuousBatcher:
    def __init__(self, model, params, cfg, *, slots: int, max_seq: int,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.active: List[Optional[Request]] = [None] * slots
        cache, _ = model.init_cache(cfg, slots, max_seq)
        self.cache = cache
        self.last_token = jnp.zeros((slots, 1), jnp.int32)
        self.stats = ServeStats()
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, cfg))
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, cfg, q_chunk=64,
                                       pad_cache_to=max_seq))

    # ------------------------------------------------------------- admission
    def _write_slot(self, slot: int, pre_cache, logits):
        """Copy a batch-1 prefill cache into slot `slot` of the live cache."""
        def write(live, new):
            if live.ndim == 0 or new.shape == live.shape:
                return new  # scalar idx: overwritten below per-leaf semantics
            # slot is the batch axis; find it: new has batch=1 where live
            # has batch=slots at the same position
            for ax in range(live.ndim):
                if new.shape[ax] == 1 and live.shape[ax] == self.slots:
                    idx = [slice(None)] * live.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return live.at[tuple(idx)].set(new.astype(live.dtype))
            return live  # shapes equal (shared idx counters etc.)
        self.cache = jax.tree_util.tree_map(write, self.cache, pre_cache)
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.last_token = self.last_token.at[slot, 0].set(tok)

    def _finished(self, req: Request, tok: int) -> bool:
        """Token-budget / EOS completion check — applied after EVERY
        append (admission included), so a request emits exactly
        max_new_tokens tokens and never holds a slot past its budget."""
        return (len(req.generated) >= req.max_new_tokens or
                (self.eos_id is not None and tok == self.eos_id))

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                logits, pre_cache = self._prefill(
                    self.params, jnp.asarray(req.prompt[None, :]))
                tok = int(jnp.argmax(logits[0, -1]))
                req.generated.append(tok)
                self.stats.prefills += 1
                self.stats.tokens_out += 1
                if self._finished(req, tok):
                    # satisfied by the prefill token alone: completed at
                    # admission, never occupies a decode slot
                    req.done = True
                    self.stats.completed += 1
                    return True
                self._write_slot(s, pre_cache, logits)
                self.active[s] = req
                return True
        return False

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One decode step over every active slot. Returns False (and
        records nothing) when no slot is active — an empty batch does no
        work and must not count as a step."""
        n_active = sum(r is not None for r in self.active)
        if n_active == 0:
            return False
        self.stats.max_active = max(self.stats.max_active, n_active)
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_token)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.last_token = next_tok[:, None]
        self.stats.steps += 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tok[s])
            req.generated.append(tok)
            self.stats.tokens_out += 1
            if self._finished(req, tok):
                req.done = True
                self.active[s] = None
                self.stats.completed += 1
        return True

    # ------------------------------------------------------------- driver
    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> ServeStats:
        pending = list(requests)
        steps = 0
        while pending or any(r is not None for r in self.active):
            progress = False
            while pending and self.submit(pending[0]):
                pending.pop(0)
                progress = True
            if self.step():
                # only decodes that ran count against the step budget
                steps += 1
                progress = True
                if steps >= max_steps:
                    break
            if not progress:
                break
        return self.stats
