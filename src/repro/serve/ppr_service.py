"""Personalized-PageRank query serving: continuous batching over walk slots.

The `ContinuousBatcher` pattern (serve/batching.py) adapted to the batched
PPR engine: a resident `BatchedPPREngine` holds Q query slots; per-user
source distributions are admitted into free slots as earlier queries'
walks terminate, every `step()` advances ALL in-flight queries with one
shard_map superstep, and completed queries land in an LRU/TTL result
cache with hot-source refresh:

  * admission — pending queries fill free slots FIFO; an optional
    `max_pending` bound rejects excess traffic (counted in
    `stats.rejected`, never silently dropped);
  * completion — a query is done when its live-walk count hits 0; its
    estimator vector is extracted once and cached;
  * cache — keyed by the canonical (sources, weights) query; a hit is
    answered immediately with the STORED vector (bit-identical to the
    compute that produced it). Entries expire after `ttl` seconds; a hit
    on an entry older than `refresh_age` additionally enqueues ONE
    background recompute (hot-source refresh) that overwrites the entry
    when it completes, so hot queries stay fresh without ever blocking.

  * elasticity — `resize(shards=...)` swaps the resident engine onto a
    grown/shrunk mesh mid-traffic: live walk buffers and visit shards are
    re-homed via `BatchedPPREngine.relayout_from`, the cache and pending
    queue (host-side) are untouched, and no query is dropped.

Time is injected (`now=`) so tests and the Poisson-traffic bench
(benchmarks/bench_serve.py) control the clock; wall time is the default.

Exactness counters: `stats.dropped_walks` mirrors the engine's buffer
overflow and `stats.admit_dropped` its admission overflow — both must
stay 0 for an exact serving run (the serve bench smoke gate fails on
any nonzero drop counter).
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh

from repro.core.distributed import AXIS
from repro.core.graph import CSRGraph
from repro.core.personalized import normalize_query
from repro.core.personalized_batch import BatchedPPREngine


def query_cache_key(sources, weights, n: int) -> Tuple:
    """Canonical cache key for a (sources, weights) query."""
    sources, weights = normalize_query(sources, weights, n)
    return (tuple(int(s) for s in sources),
            tuple(float(w) for w in weights))


@dataclasses.dataclass
class PPRRequest:
    rid: int
    sources: tuple
    weights: tuple
    t_submit: float
    refresh: bool = False          # internal hot-source refresh recompute
    t_admit: Optional[float] = None
    t_done: Optional[float] = None
    slot: Optional[int] = None
    result: Optional[np.ndarray] = None
    cached: bool = False           # answered from cache at submit time
    rejected: bool = False         # bounced by the max_pending bound
    done: bool = False

    @property
    def latency(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class PPRServeStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0             # computed completions (incl. refreshes)
    cache_hits: int = 0
    refreshes: int = 0             # hot-source recomputes enqueued
    rejected: int = 0
    supersteps: int = 0
    max_active_queries: int = 0    # peak concurrently-advancing queries
    dropped_walks: int = 0         # engine buffer overflow — must stay 0
    admit_dropped: int = 0         # engine admission overflow — must stay 0
    a2a_bytes: int = 0


class ResultCache:
    """LRU + TTL cache of PPR vectors.

    `get` returns (value, needs_refresh): `value` is None on a miss or an
    expired entry (expired entries are evicted — the caller recomputes);
    `needs_refresh` flags a HIT on an entry older than `refresh_age`
    (stale-but-servable: the caller should enqueue a background refresh).
    """

    def __init__(self, max_entries: int = 256, ttl: float = math.inf,
                 refresh_age: Optional[float] = None):
        if refresh_age is not None and refresh_age >= ttl:
            raise ValueError("refresh_age must be < ttl")
        self.max_entries = int(max_entries)
        self.ttl = float(ttl)
        self.refresh_age = refresh_age
        self._d: "OrderedDict[Tuple, Tuple[np.ndarray, float]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Tuple, now: float):
        entry = self._d.get(key)
        if entry is None:
            self.misses += 1
            return None, False
        value, stored_at = entry
        age = now - stored_at
        if age >= self.ttl:
            del self._d[key]
            self.misses += 1
            return None, False
        self._d.move_to_end(key)
        self.hits += 1
        needs_refresh = (self.refresh_age is not None
                         and age >= self.refresh_age)
        return value, needs_refresh

    def put(self, key: Tuple, value: np.ndarray, now: float) -> None:
        self._d[key] = (value, now)
        self._d.move_to_end(key)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self.evictions += 1

    def stored_at(self, key: Tuple) -> Optional[float]:
        entry = self._d.get(key)
        return None if entry is None else entry[1]


class PPRService:
    def __init__(self, graph: CSRGraph, eps: float, *, slots: int,
                 walks_per_query: int, mesh=None, cap: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 cache_entries: int = 256, ttl: float = math.inf,
                 refresh_age: Optional[float] = None,
                 max_pending: Optional[int] = None,
                 key: Optional[jnp.ndarray] = None):
        self.graph = graph
        self.eps = float(eps)
        self.engine = BatchedPPREngine(
            graph, eps, num_slots=slots, walks_per_query=walks_per_query,
            mesh=mesh, cap=cap, use_pallas=use_pallas)
        self.engine.reset(key if key is not None else jax.random.PRNGKey(0))
        self._master_key = (key if key is not None
                            else jax.random.PRNGKey(0))
        self.cache = ResultCache(cache_entries, ttl, refresh_age)
        self.pending: "deque[PPRRequest]" = deque()
        self.max_pending = max_pending
        self._slot_req: List[Optional[PPRRequest]] = [None] * slots
        self._refreshing: set = set()   # cache keys with an in-flight refresh
        self._next_rid = 0
        self.stats = PPRServeStats()

    # ------------------------------------------------------------- queries
    @property
    def busy(self) -> bool:
        return bool(self.pending) or any(
            r is not None for r in self._slot_req)

    def submit(self, sources, weights=None, *,
               now: Optional[float] = None) -> PPRRequest:
        """Submit one query. Answered immediately from the cache when
        possible (bit-identical stored vector), else queued for a slot."""
        now = time.monotonic() if now is None else now
        srcs, wts = normalize_query(sources, weights, self.graph.n)
        req = PPRRequest(rid=self._next_rid, sources=tuple(map(int, srcs)),
                         weights=tuple(map(float, wts)), t_submit=now)
        self._next_rid += 1
        self.stats.submitted += 1

        ckey = (req.sources, req.weights)
        value, needs_refresh = self.cache.get(ckey, now)
        if value is not None:
            req.result = value
            req.cached = True
            req.done = True
            req.t_done = now
            self.stats.cache_hits += 1
            if needs_refresh and ckey not in self._refreshing:
                self._enqueue_refresh(req, now)
            return req

        if (self.max_pending is not None
                and len(self.pending) >= self.max_pending):
            req.rejected = True
            req.done = True
            self.stats.rejected += 1
            return req
        self.pending.append(req)
        self._admit_pending(now)   # take a free slot immediately if any
        return req

    def _enqueue_refresh(self, hit: PPRRequest, now: float) -> None:
        refresh = PPRRequest(rid=self._next_rid, sources=hit.sources,
                             weights=hit.weights, t_submit=now,
                             refresh=True)
        self._next_rid += 1
        self._refreshing.add((hit.sources, hit.weights))
        self.pending.append(refresh)
        self.stats.refreshes += 1

    # -------------------------------------------------------------- elastic
    def resize(self, *, shards: Optional[int] = None,
               mesh: Optional[Mesh] = None) -> None:
        """Rebuild the resident engine on a resized mesh — mid-traffic.

        Pass exactly one of `shards` (the first `shards` local devices) or
        an explicit `mesh`. The new engine adopts the old one's live walk
        buffers, visit shards, and telemetry via
        `BatchedPPREngine.relayout_from`, so NOTHING is dropped: cached
        results (host-side) stay served bit-identically, in-flight
        queries keep their walks and accumulated visits and simply finish
        on the new mesh, and the pending queue admits as before. The
        production story behind it: lose or gain a host, keep serving.
        """
        if (shards is None) == (mesh is None):
            raise ValueError("pass exactly one of shards= or mesh=")
        if mesh is None:
            devs = jax.devices()
            if int(shards) > len(devs):
                raise ValueError(f"shards={shards} exceeds the "
                                 f"{len(devs)} available devices")
            mesh = Mesh(np.array(devs[:int(shards)]), (AXIS,))
        old = self.engine
        new = BatchedPPREngine(
            self.graph, self.eps, num_slots=old.Q,
            walks_per_query=old.walks_per_query, mesh=mesh,
            use_pallas=old.use_pallas)
        new.relayout_from(old)
        self.engine = new

    # ------------------------------------------------------------- stepping
    def _admit_pending(self, now: float) -> None:
        for slot in range(self.engine.Q):
            if not self.pending or self._slot_req[slot] is not None:
                continue
            req = self.pending.popleft()
            # per-request key: independent starts/steps per rid, while a
            # fixed master key keeps a whole trace reproducible
            self.engine.admit(slot, req.sources, req.weights,
                              key=jax.random.fold_in(self._master_key,
                                                     req.rid))
            req.slot = slot
            req.t_admit = now
            self._slot_req[slot] = req
            self.stats.admitted += 1

    def step(self, now: Optional[float] = None) -> List[PPRRequest]:
        """Admit what fits, advance every in-flight query one superstep,
        and return the requests completed by it (refreshes included)."""
        wall_clock = now is None
        now = time.monotonic() if wall_clock else now
        self._admit_pending(now)
        n_active = sum(r is not None for r in self._slot_req)
        if n_active == 0:
            return []
        self.stats.max_active_queries = max(
            self.stats.max_active_queries, n_active)
        active = self.engine.superstep()
        self.stats.supersteps += 1
        self.stats.a2a_bytes = self.engine.a2a_bytes
        self.stats.dropped_walks = self.engine.dropped
        self.stats.admit_dropped = self.engine.admit_dropped

        done: List[PPRRequest] = []
        # completion is timed after the superstep's device work
        now = time.monotonic() if wall_clock else now
        for slot, req in enumerate(self._slot_req):
            if req is None or active[slot] != 0:
                continue
            req.result = self.engine.extract(slot)
            req.done = True
            req.t_done = now
            ckey = (req.sources, req.weights)
            self.cache.put(ckey, req.result, now)
            self._refreshing.discard(ckey)
            self._slot_req[slot] = None
            self.stats.completed += 1
            done.append(req)
        return done

    def drain(self, max_steps: int = 100_000,
              now: Optional[float] = None) -> List[PPRRequest]:
        """Step until every pending/in-flight query completes."""
        done: List[PPRRequest] = []
        steps = 0
        while self.busy and steps < max_steps:
            done.extend(self.step(now=now))
            steps += 1
        return done

    def reset_stats(self) -> None:
        """Zero the traffic counters (the engine keeps running). Used by
        the bench to exclude compile-warmup traffic from the measured
        window; cache contents are NOT cleared (warm-cache runs)."""
        self.stats = PPRServeStats()
