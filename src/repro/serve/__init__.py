from repro.serve.batching import ContinuousBatcher, Request, ServeStats

__all__ = ["ContinuousBatcher", "Request", "ServeStats"]
