from repro.serve.batching import ContinuousBatcher, Request, ServeStats
from repro.serve.ppr_service import (PPRRequest, PPRServeStats, PPRService,
                                     ResultCache, query_cache_key)

__all__ = ["ContinuousBatcher", "Request", "ServeStats",
           "PPRRequest", "PPRServeStats", "PPRService", "ResultCache",
           "query_cache_key"]
