"""Gradient compression: int8 all-reduce with error feedback.

For bandwidth-bound data-parallel reduction, gradients are blockwise-int8
quantized before the cross-replica sum and the quantization residual is
carried to the next step (error feedback keeps the method unbiased in the
long run). Exposed as a shard_map-level primitive:

    compressed_psum(x, axis_name, residual) -> (y, new_residual)

used by the explicit-DP training variant; the default pjit path keeps XLA's
fused bf16 all-reduce (measured in §Roofline) and this primitive is the
beyond-paper lever for collective-bound cells (the payload shrinks 2x vs
bf16, 4x vs fp32).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = x.shape[0]
    pad = -(-n // QBLOCK) * QBLOCK - n
    xp = jnp.pad(x, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1) / 127.0
    q = jnp.round(xp / jnp.maximum(scale[:, None], 1e-12)).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    residual: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 psum of a flat fp32 vector (inside shard_map).

    Semantics: each shard contributes only its int8-quantized (+per-block
    scale) view; the residual is carried locally to the next call. On TPU
    the wire payload is the int8 blocks + fp32 scales (~4x smaller than
    fp32); XLA models it as the reduction of the dequantized contributions.
    """
    n = x.shape[0]
    corrected = x + residual
    q, scale = _quant(corrected)
    local = _dequant(q, scale, n)
    new_residual = corrected - local          # what quantization lost
    y = jax.lax.psum(local, axis_name)
    return y, new_residual


def compression_error(x: jnp.ndarray) -> float:
    """Single-shot quantization relative L2 error (diagnostics)."""
    q, s = _quant(x)
    err = x - _dequant(q, s, x.shape[0])
    return float(jnp.linalg.norm(err) / jnp.maximum(jnp.linalg.norm(x), 1e-12))
