"""Training step factory: microbatched grad accumulation + AdamW/ZeRO.

`make_train_step(cfg, model, adam_cfg, num_microbatches)` returns a pure
function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

with the global batch split into `num_microbatches` scanned microbatches
(fp32 gradient accumulator, full per-layer remat inside the model), then a
single optimizer application. Collective structure under pjit:
  * per-microbatch DP gradient all-reduce is deferred — the accumulator is
    sharded like the (TP-sharded) params, so XLA reduces once;
  * ZeRO: gradient reduce-scatter into the data-sharded optimizer state and
    the weight all-gather back to bf16 params (see optimizer.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import maybe_scan

from repro.train.optimizer import AdamWConfig, AdamState, apply_updates
from repro.sharding.rules import maybe_constrain


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    """[B, ...] -> [n, B/n, ...] per leaf."""
    def sp(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(cfg, model, adam_cfg: AdamWConfig,
                    num_microbatches: int = 1,
                    loss_kwargs: Optional[dict] = None) -> Callable:
    loss_kwargs = loss_kwargs or {}

    def loss_for_grad(params, micro):
        loss, metrics = model.loss_fn(params, micro, cfg, **loss_kwargs)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(params, opt_state: AdamState, batch):
        if num_microbatches > 1:
            micro = _split_microbatches(batch, num_microbatches)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: maybe_constrain(x, ("batch",) + (None,) * (x.ndim - 1)),
                    mb)
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = maybe_scan(
                acc_body, (g0, jnp.float32(0)), micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
        else:
            (loss, _), grads = grad_fn(params, batch)

        new_params, new_opt, om = apply_updates(params, grads, opt_state,
                                                adam_cfg)
        metrics = dict(loss=loss, **om)
        return new_params, new_opt, metrics

    return train_step