"""AdamW with ZeRO-sharded state and optional blockwise-int8 moments.

Memory layout per parameter (bf16 weights live in `params`):
    master  fp32  flattened + block-padded, sharded over the data axis
    m, v    fp32  flattened                — or int8 + fp32 per-block scales

This is ZeRO-1: *all* optimizer state lives flattened on the ("zero",) =
data(+pod) axis, dividing it by the data-parallel degree (256-512x on the
production meshes); each step the new bf16 weights are re-materialized from
the master (GSPMD inserts the ZeRO weight all-gather), and gradients are
resharded to the state (the reduce-scatter).

int8 moments use symmetric blockwise quantization (block 128, absmax) with
quantize-after-update — the 8-bit-optimizer recipe in pure JAX. The second
moment is stored as sqrt(v) (halves its dynamic range) and dequantized with
a half-LSB floor: entries whose true sqrt(v) quantizes to code 0 would
otherwise make m/(sqrt(v)+eps) explode — the floor bounds that error to
~2x in the safe (smaller-update) direction. For the 340B dense config this
is the difference between fitting and not fitting 256 x 16 GB (see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_moments: bool = False


def _pad_len(n: int) -> int:
    return -(-n // QBLOCK) * QBLOCK


def _flatten_pad(x: jnp.ndarray) -> jnp.ndarray:
    flat = x.astype(jnp.float32).reshape(-1)
    return jnp.zeros((_pad_len(flat.shape[0]),), jnp.float32).at[
        : flat.shape[0]].set(flat)


def quantize_blockwise(flat: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 [N] (N % QBLOCK == 0) -> (int8 [N], fp32 scales [N/QBLOCK])."""
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_blockwise(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return (q.reshape(-1, QBLOCK).astype(jnp.float32)
            * scale[:, None]).reshape(-1)


def dequantize_floor(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Non-negative dequant with a half-LSB floor (for sqrt(v) storage)."""
    s = scale[:, None]
    vals = q.reshape(-1, QBLOCK).astype(jnp.float32) * s
    return jnp.maximum(vals, 0.5 * s).reshape(-1)


class AdamState(NamedTuple):
    step: jnp.ndarray
    master: Any   # per-leaf flat fp32
    m: Any        # per-leaf flat fp32, or (int8, scales)
    v: Any


def init_state(params, cfg: AdamWConfig) -> AdamState:
    master = jax.tree_util.tree_map(_flatten_pad, params)
    if cfg.int8_moments:
        def zq(p):
            n = _pad_len(p.size)
            return (jnp.zeros((n,), jnp.int8),
                    jnp.zeros((n // QBLOCK,), jnp.float32))
        m = jax.tree_util.tree_map(zq, params)
        v = jax.tree_util.tree_map(zq, params)
    else:
        zeros = lambda p: jnp.zeros((_pad_len(p.size),), jnp.float32)
        m = jax.tree_util.tree_map(zeros, params)
        v = jax.tree_util.tree_map(zeros, params)
    return AdamState(step=jnp.zeros((), jnp.int32), master=master, m=m, v=v)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state: AdamState, cfg: AdamWConfig
                  ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new bf16 params, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    gscale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_master = treedef.flatten_up_to(state.master)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_params, new_master, new_m, new_v = [], [], [], []
    for p, g, mstr, m, v in zip(flat_p, flat_g, flat_master, flat_m, flat_v):
        gf = _flatten_pad(g) * gscale
        if cfg.int8_moments:
            m_f = dequantize_blockwise(*m)
            u = dequantize_floor(*v)        # u = sqrt(v), half-LSB floored
            v_f = u * u
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * gf
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * gf * gf
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        mstr = mstr - cfg.lr * (upd + cfg.weight_decay * mstr)
        new_master.append(mstr)
        new_m.append(quantize_blockwise(m_f) if cfg.int8_moments else m_f)
        new_v.append(quantize_blockwise(jnp.sqrt(v_f))
                     if cfg.int8_moments else v_f)
        new_params.append(mstr[: p.size].reshape(p.shape).astype(p.dtype))

    unfl = treedef.unflatten
    return (unfl(new_params),
            AdamState(step=step, master=unfl(new_master), m=unfl(new_m),
                      v=unfl(new_v)),
            dict(grad_norm=gnorm))


def state_axes(param_axes, int8_moments: bool) -> "AdamState":
    """Logical-axes tree mirroring init_state: everything on ("zero",)."""
    from repro.models.common import _is_axes_leaf

    flat = lambda _: ("zero",)
    master = jax.tree_util.tree_map(flat, param_axes, is_leaf=_is_axes_leaf)
    if int8_moments:
        mq = jax.tree_util.tree_map(lambda _: (("zero",), ("zero",)),
                                    param_axes, is_leaf=_is_axes_leaf)
        return AdamState(step=(), master=master, m=mq, v=mq)
    return AdamState(step=(), master=master, m=master, v=master)
