from repro.train.optimizer import (AdamState, AdamWConfig, apply_updates,
                                   init_state, state_axes)
from repro.train.train_step import make_train_step
from repro.train.compression import compressed_psum, compression_error

__all__ = ["AdamState", "AdamWConfig", "apply_updates", "init_state",
           "state_axes", "make_train_step", "compressed_psum",
           "compression_error"]
