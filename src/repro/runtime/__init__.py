from repro.runtime.fault_tolerance import (FailureSchedule, Heartbeat,
                                           SimulatedFailure, Supervisor,
                                           SupervisorResult)

__all__ = ["FailureSchedule", "Heartbeat", "SimulatedFailure", "Supervisor",
           "SupervisorResult"]
