from repro.runtime.fault_tolerance import (FailureSchedule, Heartbeat,
                                           SimulatedFailure, Stage,
                                           StagedState, StageSchedule,
                                           Supervisor, SupervisorResult,
                                           run_staged, staged_from_host,
                                           staged_to_host)

__all__ = ["FailureSchedule", "Heartbeat", "SimulatedFailure", "Stage",
           "StagedState", "StageSchedule", "Supervisor", "SupervisorResult",
           "run_staged", "staged_from_host", "staged_to_host"]
