"""Fault-tolerance harness: checkpoint/restart, failure injection, heartbeats.

The supervisor wraps any step-function-driven engine (the distributed
PageRank super-step loop, or the training loop) with:

  * periodic checkpoints (sync or async),
  * simulated failures (a `FailureSchedule` raising `SimulatedFailure`
    at chosen rounds — standing in for a lost pod / preempted host),
  * restart-from-latest-checkpoint recovery. Because engine state is a pure
    pytree that includes the PRNG keys, recovery replays the *identical*
    trajectory — the recovered run is bit-exact with an uninterrupted one
    (asserted in tests),
  * a heartbeat/straggler monitor: per-round wall-times are tracked and
    rounds slower than `straggler_factor` × running median are flagged.
    (Real deployments feed these flags into the engine's `work_cap`
    rebalancing — here they are surfaced as stats.)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import Checkpointer


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureSchedule:
    """Fail at the start of each listed round (once each)."""

    fail_at_rounds: List[int]
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, round_idx: int):
        if round_idx in self.fail_at_rounds and round_idx not in self._fired:
            self._fired.add(round_idx)
            raise SimulatedFailure(f"injected failure at round {round_idx}")


@dataclasses.dataclass
class Heartbeat:
    straggler_factor: float = 3.0
    times: List[float] = dataclasses.field(default_factory=list)
    stragglers: List[int] = dataclasses.field(default_factory=list)

    def record(self, round_idx: int, dt: float):
        self.times.append(dt)
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.straggler_factor * med:
                self.stragglers.append(round_idx)


@dataclasses.dataclass
class SupervisorResult:
    state: Any
    rounds: int
    restarts: int
    checkpoints_written: int
    stragglers: List[int]


class Supervisor:
    """Generic checkpoint-restart driver.

    step_fn(state) -> (state, done: bool)
    to_host(state) -> dict            (for checkpointing)
    from_host(dict) -> state          (for recovery)
    """

    def __init__(self, step_fn: Callable, to_host: Callable, from_host: Callable,
                 checkpointer: Checkpointer, *, checkpoint_every: int = 10,
                 max_restarts: int = 16, async_checkpoints: bool = False,
                 failure_schedule: Optional[FailureSchedule] = None):
        self.step_fn = step_fn
        self.to_host = to_host
        self.from_host = from_host
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.async_checkpoints = async_checkpoints
        self.failures = failure_schedule
        self.heartbeat = Heartbeat()

    def run(self, state: Any, *, max_rounds: int = 100_000) -> SupervisorResult:
        restarts = 0
        ckpts = 0
        round_idx = 0
        # round-0 checkpoint so recovery is always possible
        self.ckpt.save(0, self.to_host(state), blocking=True)
        ckpts += 1
        while round_idx < max_rounds:
            t0 = time.perf_counter()
            try:
                if self.failures is not None:
                    self.failures.maybe_fail(round_idx)
                state, done = self.step_fn(state)
                round_idx += 1
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                flat, manifest = self.ckpt.restore()
                state = self.from_host(flat)
                round_idx = int(manifest["step"])
                continue
            self.heartbeat.record(round_idx, time.perf_counter() - t0)
            if round_idx % self.checkpoint_every == 0:
                self.ckpt.save(round_idx, self.to_host(state),
                               blocking=not self.async_checkpoints)
                ckpts += 1
            if done:
                break
        self.ckpt.wait()
        return SupervisorResult(state=state, rounds=round_idx, restarts=restarts,
                                checkpoints_written=ckpts,
                                stragglers=self.heartbeat.stragglers)
