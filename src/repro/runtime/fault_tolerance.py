"""Fault-tolerance harness: checkpoint/restart, failure injection, heartbeats.

The supervisor wraps any step-function-driven engine (the distributed
PageRank super-step loop, or the training loop) with:

  * periodic checkpoints (sync or async),
  * simulated failures (a `FailureSchedule` raising `SimulatedFailure`
    at chosen rounds — standing in for a lost pod / preempted host),
  * restart-from-latest-checkpoint recovery. Because engine state is a pure
    pytree that includes the PRNG keys, recovery replays the *identical*
    trajectory — the recovered run is bit-exact with an uninterrupted one
    (asserted in tests),
  * a heartbeat/straggler monitor: per-round wall-times are tracked and
    rounds slower than `straggler_factor` × running median are flagged.
    (Real deployments feed these flags into the engine's `work_cap`
    rebalancing — here they are surfaced as stats.)

Multi-stage schedules: engines whose run is a *sequence of named phases*
with different step functions and different device buffers per phase (the
3-phase stitching engines) compose per-phase step functions with
`StageSchedule` into one supervisor-drivable step function over a
stage-tagged `StagedState`. Snapshots carry the stage tag, the stage's
device buffers, and the host-side telemetry accumulators, so a killed run
resumes mid-phase and replays the identical trajectory.

Elastic resume: a `StagedState` additionally declares, per stage, a
`checkpoint.LayoutSpec` schema describing how each device buffer is laid
out across the mesh (walk lanes / vertex shards / coupon slots /
per-shard keys / replicated — see `checkpoint/elastic.py`), plus the
shard count it was built for. `Supervisor.run(resume=True)` compares the
shard count recorded in the snapshot manifest against the live mesh and,
on mismatch, routes the restored flat dict through the schema-driven
`checkpoint.relayout_staged_flat` before `from_host` — so a run killed on
P shards resumes on P' shards (grown or shrunk), then immediately
re-snapshots on the new layout so any later crash recovers new-mesh
state.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import (Checkpointer, pack_json, relayout_staged_flat,
                              unpack_json)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureSchedule:
    """Fail at the start of each listed round (once each)."""

    fail_at_rounds: List[int]
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, round_idx: int):
        if round_idx in self.fail_at_rounds and round_idx not in self._fired:
            self._fired.add(round_idx)
            raise SimulatedFailure(f"injected failure at round {round_idx}")


@dataclasses.dataclass
class Heartbeat:
    straggler_factor: float = 3.0
    times: List[float] = dataclasses.field(default_factory=list)
    stragglers: List[int] = dataclasses.field(default_factory=list)

    def record(self, round_idx: int, dt: float):
        self.times.append(dt)
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.straggler_factor * med:
                self.stragglers.append(round_idx)


@dataclasses.dataclass
class Stage:
    """One named phase of a multi-stage engine.

    `step(state) -> (state, stage_done)` runs one super-step of this phase;
    `on_done(state) -> state` is the host-side transition that rebuilds the
    device buffers for the next phase (initial placements, bitmap
    broadcasts, ...) once the phase reports done.
    """

    name: str
    step: Callable[[Any], Tuple[Any, bool]]
    on_done: Optional[Callable[[Any], Any]] = None


@dataclasses.dataclass
class StagedState:
    """Machine state threaded through a `StageSchedule`: the tag of the
    stage currently running, that stage's device buffers (a flat
    name -> array dict), and JSON-able host accumulators (round counters,
    wire volumes, per-round records). Snapshots carry all three.

    `layouts` (optional) maps stage name -> {buffer name ->
    `checkpoint.LayoutSpec`}, declaring how each stage's buffers are laid
    out across the mesh, and `shards` records the mesh size the state was
    built for; together they make snapshots mesh-size-agnostic — the
    supervisor routes a resumed snapshot onto a resized mesh through
    `checkpoint.relayout_staged_flat`. Engines that never resume
    elastically may leave both unset."""

    stage: str
    arrays: Dict[str, Any]
    host: Dict[str, Any]
    layouts: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    shards: Optional[int] = None


class StageSchedule:
    """Compose per-phase step functions into ONE supervisor-drivable step
    function over a stage-tagged `StagedState`.

    Each call runs one super-step of the current stage; when a stage
    reports done its `on_done` transition fires and the machine advances
    to the next stage in order. The composed step function returns
    done=True only when the last stage completes, so the global round
    index seen by `Supervisor` (checkpoint cadence, `FailureSchedule`
    rounds) spans all phases.
    """

    def __init__(self, stages: List[Stage]):
        if not stages:
            raise ValueError("empty stage schedule")
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        self.stages = stages
        self._index = {s.name: i for i, s in enumerate(stages)}

    @property
    def first_stage(self) -> str:
        return self.stages[0].name

    def step(self, state: StagedState) -> Tuple[StagedState, bool]:
        i = self._index[state.stage]
        stage = self.stages[i]
        specs = state.layouts.get(state.stage)
        if specs is not None and set(specs) != set(state.arrays):
            # an uncovered buffer would silently vanish from elastic
            # snapshots; a spec without a buffer means the schema rotted
            missing = set(state.arrays) - set(specs)
            extra = set(specs) - set(state.arrays)
            raise ValueError(
                f"stage '{state.stage}' layout schema out of sync with its "
                f"device buffers: uncovered buffers {sorted(missing)}, "
                f"dangling specs {sorted(extra)}")
        state, stage_done = stage.step(state)
        if not stage_done:
            return state, False
        if stage.on_done is not None:
            state = stage.on_done(state)
        if i + 1 == len(self.stages):
            return state, True
        state.stage = self.stages[i + 1].name
        return state, False


def staged_to_host(state: StagedState) -> dict:
    """Checkpoint payload for a `StagedState`: a pure pytree of arrays —
    device buffers as-is, stage tag + host accumulators as JSON leaves."""
    return dict(arrays={k: np.asarray(v) for k, v in state.arrays.items()},
                stage=pack_json(state.stage), host=pack_json(state.host))


def staged_from_host(flat: Dict[str, np.ndarray],
                     put: Callable[[str, np.ndarray], Any],
                     like: Optional[StagedState] = None) -> StagedState:
    """Rebuild a `StagedState` from a restored flat checkpoint dict.
    `put(name, host_array) -> device array` re-establishes each buffer's
    sharding (the stage layouts are engine knowledge). `like` donates the
    layout schema and live shard count (not checkpointed — they describe
    the CURRENT mesh, which on an elastic resume differs from the one the
    snapshot was written under)."""
    arrays = {k.split("/", 1)[1]: put(k.split("/", 1)[1], v)
              for k, v in flat.items() if k.startswith("arrays/")}
    return StagedState(stage=unpack_json(flat["stage"]), arrays=arrays,
                       host=unpack_json(flat["host"]),
                       layouts=like.layouts if like is not None else {},
                       shards=like.shards if like is not None else None)


@dataclasses.dataclass
class SupervisorResult:
    state: Any
    rounds: int
    restarts: int
    checkpoints_written: int
    stragglers: List[int]


class Supervisor:
    """Generic checkpoint-restart driver.

    step_fn(state) -> (state, done: bool)
    to_host(state) -> dict            (for checkpointing)
    from_host(dict) -> state          (for recovery)
    meta_fn() -> dict                 (manifest metadata on every save;
                                       a "shards" entry enables elastic
                                       mismatch detection on resume)
    relayout(flat, old_shards) -> flat  (re-layout a snapshot written
                                       under `old_shards` onto the live
                                       mesh; consulted only on resume
                                       when the manifest's recorded
                                       shard count differs from
                                       meta_fn()["shards"])
    """

    def __init__(self, step_fn: Callable, to_host: Callable, from_host: Callable,
                 checkpointer: Checkpointer, *, checkpoint_every: int = 10,
                 max_restarts: int = 16, async_checkpoints: bool = False,
                 failure_schedule: Optional[FailureSchedule] = None,
                 meta_fn: Optional[Callable[[], dict]] = None,
                 relayout: Optional[Callable[[dict, int], dict]] = None):
        self.step_fn = step_fn
        self.to_host = to_host
        self.from_host = from_host
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.async_checkpoints = async_checkpoints
        self.failures = failure_schedule
        self.meta_fn = meta_fn
        self.relayout = relayout
        self.heartbeat = Heartbeat()

    def _meta(self) -> dict:
        return self.meta_fn() if self.meta_fn is not None else {}

    def run(self, state: Any, *, max_rounds: int = 100_000,
            resume: bool = False) -> SupervisorResult:
        restarts = 0
        ckpts = 0
        round_idx = 0
        if resume:
            # cold start from a previous (killed) run's latest snapshot;
            # an empty dir is an error, not a silent fresh run — a typo'd
            # path must not quietly discard the resume intent
            if self.ckpt.latest_step() is None:
                raise FileNotFoundError(
                    f"resume requested but no snapshots under "
                    f"{self.ckpt.base_dir}")
            flat, manifest = self.ckpt.restore()
            round_idx = int(manifest["step"])
            old_shards = (manifest.get("metadata") or {}).get("shards")
            live_shards = self._meta().get("shards")
            if (old_shards is not None and live_shards is not None
                    and int(old_shards) != int(live_shards)):
                if self.relayout is None:
                    raise ValueError(
                        f"snapshot under {self.ckpt.base_dir} was written "
                        f"at {old_shards} shards but the live mesh has "
                        f"{live_shards} and no relayout hook is configured")
                flat = self.relayout(flat, int(old_shards))
                state = self.from_host(flat)
                # re-anchor immediately: if we crash after this point,
                # recovery must restore NEW-mesh state, not the old layout
                self.ckpt.save(round_idx, self.to_host(state),
                               metadata=self._meta(), blocking=True)
                ckpts += 1
            else:
                state = self.from_host(flat)
        else:
            # fresh run: refuse a directory that already holds snapshots —
            # recovery must never restore foreign state, and silently
            # wiping them would destroy another run's recovery points
            if self.ckpt.latest_step() is not None:
                raise FileExistsError(
                    f"{self.ckpt.base_dir} already holds snapshots; pass "
                    f"resume=True to continue that run, or clear the "
                    f"directory (Checkpointer.clear()) to start fresh")
            # round-0 checkpoint so recovery is always possible
            self.ckpt.save(0, self.to_host(state), metadata=self._meta(),
                           blocking=True)
            ckpts += 1
        while round_idx < max_rounds:
            t0 = time.perf_counter()
            try:
                if self.failures is not None:
                    self.failures.maybe_fail(round_idx)
                state, done = self.step_fn(state)
                round_idx += 1
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                flat, manifest = self.ckpt.restore()
                state = self.from_host(flat)
                round_idx = int(manifest["step"])
                continue
            self.heartbeat.record(round_idx, time.perf_counter() - t0)
            # always snapshot on `done` — a run finishing between periodic
            # intervals must still leave the directory reflecting its
            # final state (blocking: nothing overlaps a finished run)
            if done or round_idx % self.checkpoint_every == 0:
                self.ckpt.save(round_idx, self.to_host(state),
                               metadata=self._meta(),
                               blocking=done or not self.async_checkpoints)
                ckpts += 1
            if done:
                break
        self.ckpt.wait()
        return SupervisorResult(state=state, rounds=round_idx, restarts=restarts,
                                checkpoints_written=ckpts,
                                stragglers=self.heartbeat.stragglers)


def run_staged(schedule: StageSchedule, state: StagedState,
               put: Callable[[str, np.ndarray], Any], *,
               checkpoint_dir: Optional[str] = None,
               fail_at: Optional[Sequence[int]] = None,
               checkpoint_every: int = 10, max_restarts: int = 16,
               resume: bool = False, max_rounds: int = 100_000,
               tmp_prefix: str = "staged_ckpt_") -> Tuple[StagedState, int,
                                                          int]:
    """Drive a `StageSchedule` to completion: plain loop when no fault
    tolerance is requested, otherwise under the checkpoint-restart
    `Supervisor` with stage-tagged `staged_to_host` snapshots.

    `put(name, host_array)` re-establishes per-buffer sharding on restore.
    When `state` declares `shards` + `layouts`, snapshots record the mesh
    size and `resume=True` from a snapshot written at a DIFFERENT shard
    count re-layouts it onto the live mesh (see `checkpoint/elastic.py`).
    Returns (final state, restarts, checkpoints_written)."""
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir (there is no "
                         "snapshot to cold-start from)")
    if checkpoint_dir is None and not fail_at:
        rounds = 0
        done = False
        while not done and rounds < max_rounds:   # same bound as Supervisor
            state, done = schedule.step(state)
            rounds += 1
        return state, 0, 0
    # fail_at without a caller dir: snapshots go to a private temp dir the
    # caller has no handle to, so remove it once the run is over
    tmp_dir = tempfile.mkdtemp(prefix=tmp_prefix) \
        if checkpoint_dir is None else None
    meta_fn = ((lambda: dict(shards=int(state.shards)))
               if state.shards is not None else None)
    relayout = None
    if state.shards is not None and state.layouts:
        live_shards, layouts = int(state.shards), state.layouts
        relayout = (lambda flat, old_shards: relayout_staged_flat(
            flat, old_shards, live_shards, layouts))
    try:
        sup = Supervisor(
            schedule.step, staged_to_host,
            lambda flat: staged_from_host(flat, put, like=state),
            Checkpointer(checkpoint_dir or tmp_dir),
            checkpoint_every=checkpoint_every, max_restarts=max_restarts,
            failure_schedule=FailureSchedule(list(fail_at)) if fail_at
            else None, meta_fn=meta_fn, relayout=relayout)
        res = sup.run(state, max_rounds=max_rounds, resume=resume)
    finally:
        if tmp_dir is not None:
            shutil.rmtree(tmp_dir, ignore_errors=True)
    return res.state, res.restarts, res.checkpoints_written
