"""H2O-Danube3-4B (arXiv:2401.16818; unverified) — llama+mistral mix, SWA.

24L, d_model 3840, 32Q/8KV (head 120), d_ff 10240, vocab 32000,
sliding window 4096 => bounded decode cache => long_500k RUNS.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    head_dim=120, d_ff=10240, vocab_size=32000,
    attention="gqa", mlp="swiglu", sliding_window=4096,
    rope_theta=10_000.0,
)
