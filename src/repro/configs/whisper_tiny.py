"""Whisper-tiny (arXiv:2212.04356; unverified) — enc-dec audio backbone.

4+4L, d_model 384, 6H MHA, d_ff 1536, vocab 51865. Conv frontend is a STUB:
input_specs() provides 1500 precomputed frame embeddings. (Positional
encoding adapted to RoPE — backbone exercise per DESIGN.md.)
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    attention="gqa", mlp="gelu",
    encoder_layers=4, encoder_seq=1500,
)
