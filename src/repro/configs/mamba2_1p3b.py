"""Mamba2-1.3B (arXiv:2405.21060; unverified) — SSD, attention-free.

48L, d_model 2048, d_state 128, expand 2 (d_inner 4096), headdim 64
(64 SSD heads), vocab 50280. O(1) decode state => long_500k RUNS.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=64, num_kv_heads=64,
    d_ff=0, vocab_size=50280,
    attention="none", ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_chunk=128, conv_kernel=4, tie_embeddings=True,
)
