"""Qwen3-32B (hf:Qwen/Qwen3-8B family; hf) — dense GQA with qk-norm.

64L, d_model 5120, 64Q/8KV (head 128; Q proj 8192 decoupled from d_model),
d_ff 25600, vocab 151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936,
    attention="gqa", qk_norm=True, mlp="swiglu",
    rope_theta=1_000_000.0,
)
