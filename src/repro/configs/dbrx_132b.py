"""DBRX 132B (hf:databricks/dbrx-base; unverified) — fine-grained MoE.

40L, d_model 6144, 48Q/8KV GQA, 16 experts top-4 (d_ff 10752), vocab 100352.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    attention="gqa", mlp="swiglu",
    num_experts=16, num_experts_per_tok=4, moe_d_ff=10752,
    rope_theta=500_000.0,
)
