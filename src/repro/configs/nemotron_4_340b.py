"""Nemotron-4 340B (arXiv:2402.16819; unverified) — dense, squared-ReLU.

96L, d_model 18432, 96Q/8KV (head 192), d_ff 73728 (non-gated), vocab 256000.
Training fits 256x16GB only with blockwise-int8 Adam states + per-device
microbatch 1 (see train/optimizer.py).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    head_dim=192, d_ff=73728, vocab_size=256000,
    attention="gqa", mlp="squared_relu",
    rope_theta=10_000.0,
)
