"""Assigned input shapes x input_specs (ShapeDtypeStruct stand-ins).

Four shapes per LM architecture (40 cells):
    train_4k     seq 4096,    global_batch 256   (train_step)
    prefill_32k  seq 32768,   global_batch 32    (serve prefill)
    decode_32k   cache 32768, global_batch 128   (serve decode, 1 new token)
    long_500k    cache 524288, global_batch 1    (decode; sub-quadratic only)

`long_500k` requires bounded decode state: it runs for ssm / hybrid /
sliding-window archs and is skipped (recorded) for pure full-attention
archs — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.is_subquadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   batch dict for loss_fn (tokens/labels + modality extras)
    prefill: prompt tokens (+ modality extras)
    decode:  one new token; the KV cache comes from the model's
             init_cache eval_shape (see launch/dryrun.py).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = dict(tokens=_sds((B, S), jnp.int32),
                     labels=_sds((B, S), jnp.int32))
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
        if cfg.family == "vlm":
            # image prefix + text = S total positions
            batch["tokens"] = _sds((B, S - cfg.num_image_tokens), jnp.int32)
            batch["labels"] = _sds((B, S - cfg.num_image_tokens), jnp.int32)
            batch["img_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                       jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        spec = dict(tokens=_sds((B, S), jnp.int32))
        if cfg.family == "audio":
            spec["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                  jnp.bfloat16)
        if cfg.family == "vlm":
            spec["tokens"] = _sds((B, S - cfg.num_image_tokens), jnp.int32)
            spec["img_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                      jnp.bfloat16)
        return spec
    # decode: one token; cache built separately via init_cache eval_shape
    return dict(token=_sds((B, 1), jnp.int32))
