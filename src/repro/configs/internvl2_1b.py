"""InternVL2-1B (arXiv:2404.16821; hf) — VLM, Qwen2-0.5B text backbone.

24L, d_model 896, 14Q/2KV (head 64), d_ff 4864, vocab 151655.
InternViT frontend is a STUB: input_specs() provides 256 precomputed patch
embeddings per image, prepended to the text stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151655,
    attention="gqa", pad_q_heads_to=16, qkv_bias=True, mlp="swiglu",
    num_image_tokens=256, tie_embeddings=True,
    rope_theta=1_000_000.0,
)
