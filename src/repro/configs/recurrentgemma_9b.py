"""RecurrentGemma-9B (arXiv:2402.19427 Griffin; unverified) — hybrid.

38 blocks in (RG-LRU, RG-LRU, local-attn) pattern, d_model 4096,
16Q/1KV MQA local attention (window 2048), d_ff 12288 (GeGLU),
lru_width 4096, vocab 256000. Bounded state => long_500k RUNS.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    attention="gqa", mlp="geglu",
    block_pattern=("rglru", "rglru", "local"),
    lru_width=4096, local_window=2048, conv_kernel=4,
)
