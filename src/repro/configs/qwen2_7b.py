"""Qwen2-7B (arXiv:2407.10671; hf) — dense GQA with QKV bias.

28L, d_model 3584, 28Q/4KV (head 128), d_ff 18944, vocab 152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064,
    attention="gqa", pad_q_heads_to=32, qkv_bias=True, mlp="swiglu",
    rope_theta=1_000_000.0,
)
