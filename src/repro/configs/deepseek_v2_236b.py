"""DeepSeek-V2 236B (arXiv:2405.04434; hf) — MoE with MLA.

60L, d_model 5120, 128 heads, MLA (kv_lora 512, rope-dim 64), vocab 102400.
MoE: 160 routed experts (d_ff 1536) top-6 + 2 shared; first layer dense
(d_ff 12288). 236B total / ~21B active.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    attention="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    head_dim=192,  # nope + rope
    mlp="swiglu",
    num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, first_dense_layers=1,
    rope_theta=10_000.0,
)
