"""Config registry for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeConfig, input_specs, shape_applicable

from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.mamba2_1p3b import CONFIG as _mamba2
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.internvl2_1b import CONFIG as _internvl
from repro.configs.whisper_tiny import CONFIG as _whisper

ARCHS: Dict[str, ArchConfig] = {c.name: c for c in [
    _deepseek, _dbrx, _qwen2, _nemotron, _danube, _qwen3, _mamba2, _rgemma,
    _internvl, _whisper,
]}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests: few layers, narrow
    widths, tiny vocab/experts — structure preserved."""
    cfg = get_config(name)
    reps = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.block_pattern
                       else len(cfg.block_pattern) + 1),
        d_model=128, num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32, d_ff=256, vocab_size=512,
        pad_q_heads_to=None,  # production TP-divisibility padding off
    )
    if cfg.num_experts:
        # capacity 4.0: no token drops at smoke scale, so incremental decode
        # is exactly comparable with the full forward
        reps |= dict(num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
                     first_dense_layers=min(cfg.first_dense_layers, 1),
                     capacity_factor=4.0)
    if cfg.attention == "mla":
        reps |= dict(q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                     qk_nope_head_dim=32, v_head_dim=32, head_dim=48)
    if cfg.family == "ssm":
        reps |= dict(num_heads=8, num_kv_heads=8, ssm_state=16, ssm_headdim=32,
                     ssm_chunk=16, d_model=128)
    if cfg.family == "hybrid":
        reps |= dict(lru_width=128, local_window=32,
                     num_layers=len(cfg.block_pattern) + 1)
    if cfg.encoder_layers:
        reps |= dict(encoder_layers=2, encoder_seq=24)
    if cfg.num_image_tokens:
        reps |= dict(num_image_tokens=8)
    if cfg.sliding_window:
        reps |= dict(sliding_window=16)
    return dataclasses.replace(cfg, **reps)


__all__ = ["ARCHS", "ArchConfig", "SHAPES", "ShapeConfig", "get_config",
           "reduced_config", "input_specs", "shape_applicable"]
