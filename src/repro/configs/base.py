"""Architecture configuration schema for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    # --- attention flavor ---
    attention: str = "gqa"                  # gqa | mla | none
    # pad the q-head dim to this count with zero (masked) heads so it
    # divides the TP degree — mathematically exact: padded heads are
    # masked before the output projection, so they contribute nothing and
    # receive zero gradient (§Perf qwen2 hillclimb)
    pad_q_heads_to: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None    # SWA width (tokens), None = full
    rope_theta: float = 10_000.0
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MLP flavor ---
    mlp: str = "swiglu"                     # swiglu | geglu | squared_relu | gelu
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                       # per-expert hidden (0 = d_ff)
    first_dense_layers: int = 0             # leading dense layers (DeepSeek)
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()     # e.g. ("rglru","rglru","local")
    lru_width: Optional[int] = None
    local_window: int = 2048
    # --- enc-dec (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                    # fixed frame count (stub frontend)
    # --- VLM ---
    num_image_tokens: int = 0               # stub patch-embedding prefix
    # --- training details ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run the long_500k decode shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # every arch in the pool has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and memory-budget sanity checks."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_headdim
            per = (d * (2 * d_in + 2 * self.ssm_state * 1 + nheads)  # in_proj-ish
                   + d_in * self.conv_kernel + d_in * d + 2 * d)
            # in_proj: d -> (2*d_in + 2*n_groups*state + nheads)
            per = d * (2 * d_in + 2 * self.ssm_state + nheads) + \
                d_in * self.conv_kernel + d_in * d + 2 * d + nheads * 2
            return total + L * per
        # attention params (padded q-heads included — they are real arrays)
        Hp = max(self.pad_q_heads_to or 0, self.num_heads)
        if self.attention == "mla":
            q_in = self.q_lora_rank or d
            attn = (d * self.q_lora_rank if self.q_lora_rank else 0)
            attn += q_in * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            attn += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            attn += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            attn += self.num_heads * self.v_head_dim * d
        else:
            attn = d * hd * (Hp + 2 * self.num_kv_heads) + Hp * hd * d
        # mlp params
        gated = self.mlp in ("swiglu", "geglu")
        dense_mlp = d * self.d_ff * (3 if gated else 2)
        if self.num_experts:
            eff = self.moe_d_ff or self.d_ff
            moe_mlp = self.num_experts * d * eff * (3 if gated else 2)
            moe_mlp += self.num_shared_experts * d * eff * (3 if gated else 2)
            moe_mlp += d * self.num_experts  # router
            n_moe = L - self.first_dense_layers
            total += n_moe * (attn + moe_mlp) + self.first_dense_layers * (attn + dense_mlp)
        else:
            total += L * (attn + dense_mlp)
        if self.family == "hybrid":
            pass  # close enough for roofline purposes; rglru ≈ attn-sized
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_mlp)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        gated = self.mlp in ("swiglu", "geglu")
        eff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        all_experts = (L - self.first_dense_layers) * self.num_experts * d * eff * (3 if gated else 2)
        active_experts = (L - self.first_dense_layers) * self.num_experts_per_tok * d * eff * (3 if gated else 2)
        return full - all_experts + active_experts
