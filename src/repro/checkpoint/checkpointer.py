"""Sharded checkpointing with manifest + async writer.

Layout of a checkpoint directory:

    <dir>/step_000042/
        manifest.json     — step, user metadata, tree paths, shapes/dtypes
        arrays.npz        — one entry per leaf (path-string keys)

Design notes for the 1000+-node setting (documented, simulated here):
  * each host writes only its local shards (`save(..., shard_slice=...)`);
    on this single-host container that degenerates to one file;
  * writes go to a temp dir + atomic rename so a mid-write failure never
    corrupts the latest checkpoint;
  * an async writer thread overlaps serialization with compute — the caller
    hands over host copies (jax.device_get) so no device buffer is held.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def pack_json(obj: Any) -> np.ndarray:
    """Encode a JSON-able host object (stage tags, telemetry accumulators)
    as a uint8 leaf, so multi-stage engine snapshots stay a pure
    pytree-of-arrays that `save`/`restore` can roundtrip through npz."""
    return np.frombuffer(json.dumps(obj).encode("utf-8"),
                         dtype=np.uint8).copy()


def unpack_json(arr: Any) -> Any:
    return json.loads(np.asarray(arr, dtype=np.uint8)
                      .tobytes().decode("utf-8"))


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path) or "leaf"
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bfloat16: numpy can't serialize — widen
            arr = np.asarray(jax.numpy.asarray(leaf).astype("float32"))
        flat[key] = arr
    return flat


class Checkpointer:
    def __init__(self, base_dir: str, *, keep_last: int = 3):
        self.base_dir = base_dir
        self.keep_last = keep_last
        os.makedirs(base_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------- save
    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None,
             blocking: bool = True) -> str:
        flat = _flatten(jax.device_get(tree))
        meta = dict(step=int(step), time=time.time(),
                    metadata=metadata or {},
                    keys={k: [list(v.shape), str(v.dtype)] for k, v in flat.items()})

        def _write():
            final = os.path.join(self.base_dir, f"step_{step:09d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        return os.path.join(self.base_dir, f"step_{step:09d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def clear(self):
        """Remove every existing snapshot (fresh-run semantics): a run
        that starts from round 0 must never recover from a stale snapshot
        left in a reused directory by a previous run."""
        self.wait()
        for name in os.listdir(self.base_dir):
            if name.startswith("step_"):
                shutil.rmtree(os.path.join(self.base_dir, name),
                              ignore_errors=True)

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.base_dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.base_dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> tuple[dict, dict]:
        """Returns (flat {path: np.ndarray}, manifest)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.base_dir}")
        d = os.path.join(self.base_dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return flat, manifest


def restore_into(tree: Any, flat: Dict[str, np.ndarray],
                 put: Optional[Callable] = None) -> Any:
    """Rebuild `tree`'s structure from a flat checkpoint dict, preserving
    each leaf's sharding via device_put to the like-leaf's sharding."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new_leaves = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path) or "leaf"
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # bf16 narrow-back
        if put is not None:
            new_leaves.append(put(arr, leaf))
        elif hasattr(leaf, "sharding"):
            new_leaves.append(jax.device_put(arr, leaf.sharding))
        else:
            new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in
                                                  zip(leaves, new_leaves)])
