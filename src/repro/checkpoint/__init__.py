from repro.checkpoint.checkpointer import (Checkpointer, pack_json,
                                           restore_into, unpack_json)
from repro.checkpoint.elastic import (LayoutSpec, derive_shard_keys,
                                      pagerank_state_specs, relayout_arrays,
                                      relayout_pagerank_state,
                                      relayout_staged_flat)

__all__ = ["Checkpointer", "LayoutSpec", "derive_shard_keys", "pack_json",
           "pagerank_state_specs", "relayout_arrays",
           "relayout_pagerank_state", "relayout_staged_flat", "restore_into",
           "unpack_json"]
