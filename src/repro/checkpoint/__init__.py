from repro.checkpoint.checkpointer import (Checkpointer, pack_json,
                                           restore_into, unpack_json)
from repro.checkpoint.elastic import relayout_pagerank_state

__all__ = ["Checkpointer", "pack_json", "restore_into", "unpack_json",
           "relayout_pagerank_state"]
