from repro.checkpoint.checkpointer import Checkpointer, restore_into
from repro.checkpoint.elastic import relayout_pagerank_state

__all__ = ["Checkpointer", "restore_into", "relayout_pagerank_state"]
