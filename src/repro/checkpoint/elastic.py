"""Elastic re-layout: resume a checkpoint on a different mesh size.

Training state: checkpoints hold full (host-gathered) arrays, so re-layout
is a `device_put` with the new mesh's NamedSharding — handled by
`checkpointer.restore_into`.

PageRank engine state is mesh-shaped ([P, cap] walk buffers, [P, n_loc]
visit shards), so resizing P requires real repartitioning — implemented
here: walks are re-bucketed by their new owner shard, visit counters are
re-split along the vertex axis. Exactness: the multiset of live walks and
the per-vertex zeta are preserved bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np


def relayout_pagerank_state(host_state: Dict, n: int, new_shards: int,
                            cap: int | None = None) -> Dict:
    pos = np.asarray(host_state["pos"])        # [P_old, cap_old]
    zeta = np.asarray(host_state["zeta"])      # [P_old, n_loc_old]
    old_shards, old_cap = pos.shape
    live = pos[pos >= 0]

    n_loc = math.ceil(n / new_shards)
    n_pad = n_loc * new_shards
    if cap is None:
        cap = max(old_cap * old_shards // new_shards + new_shards * 64, 256)

    new_pos = np.full((new_shards, cap), -1, dtype=np.int32)
    for p in range(new_shards):
        mine = live[(live // n_loc) == p]
        if len(mine) > cap:
            raise ValueError(f"elastic relayout overflow on shard {p}: "
                             f"{len(mine)} walks > cap {cap}")
        new_pos[p, : len(mine)] = mine

    zeta_flat = zeta.reshape(-1)[:n]
    zeta_pad = np.concatenate([zeta_flat,
                               np.zeros(n_pad - n, dtype=zeta_flat.dtype)])
    new_zeta = zeta_pad.reshape(new_shards, n_loc)

    # fresh independent per-shard keys derived from the old ones
    old_keys = np.asarray(host_state["key"]).reshape(-1)
    seed = int(np.bitwise_xor.reduce(old_keys.astype(np.uint32))) & 0x7FFFFFFF
    import jax
    new_keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed), new_shards))

    return dict(pos=new_pos, zeta=new_zeta, key=new_keys,
                round=host_state["round"], dropped=host_state["dropped"],
                waited=host_state["waited"])
