"""Elastic re-layout: resume a checkpoint on a different mesh size.

Training state: checkpoints hold full (host-gathered) arrays, so re-layout
is a `device_put` with the new mesh's NamedSharding — handled by
`checkpointer.restore_into`.

PageRank engine state is mesh-shaped, so resizing the shard count P is a
real repartitioning problem. Every engine buffer is one of a small set of
LAYOUT KINDS, declared per stage by the engine as a `LayoutSpec` schema on
its `runtime.StagedState` (see `StagedState.layouts`); `relayout_arrays`
is the schema-driven repartitioner the `runtime.Supervisor` routes a
resumed snapshot through when the manifest's recorded mesh shape differs
from the live mesh:

  ``walk``            [P, cap] lanes of global vertex ids (-1 = empty).
                      Live walks are re-bucketed by their new owner shard
                      (owner(v) = v // n_loc'), packed in sorted order so
                      the layout is CANONICAL — relayout P -> P' -> P is
                      bit-exact. Walks are anonymous (Lemma 1), so the
                      re-ordering is semantically free. The per-shard cap
                      auto-grows past the heuristic/target whenever walk
                      skew demands it: an elastic resume never fails
                      because one shard attracted too many walks.
  ``walk_aux``        a companion lane of a ``walk`` buffer (e.g. the
                      query-id lane of the batched PPR engine); it follows
                      the primary's placement exactly. Declared via the
                      primary's ``aux=(name, ...)``.
  ``vertex``          [P, n_loc, *rest] vertex-sharded values (zeta, walk
                      counts, ...). Re-split along the contiguous vertex
                      partition: flatten, truncate the old padding at n,
                      re-pad, re-split. Bit-exact both ways.
  ``slot``            [P, S_loc_pad, *rest] coupon-pool-slot-indexed
                      buffers of the 3-phase engines (pos/alive/traj/used/
                      dest/cterm). The pool layout is a pure function of
                      the per-vertex pool sizes (``pool``) and P — vertex
                      v's coupons occupy contiguous slots at
                      pstart[owner(v), v_loc] — so coupon (v, j) has a
                      deterministic slot under EVERY mesh size and the
                      re-layout is a bit-exact bijection.
  ``key``             [P, 2] per-shard PRNG keys. New keys are derived by
                      `fold_in(PRNGKey(hash(old keys)), shard)` — see
                      `derive_shard_keys`. One-way: the resumed trajectory
                      is fresh (statistically identical), not a replay.
  ``replicated_key``  [P, 2] where every shard carries the SAME key (the
                      count-state engine's layout-independent RNG): row 0
                      is tiled to the new P, so the per-vertex
                      counter-based draws continue bit-exactly on any
                      mesh size.
  ``replicated``      replicated scalars/arrays (round counters, drop
                      counters) — unchanged.

`relayout_pagerank_state` (the original walk-engine entry point) is kept
as a thin wrapper over the same schema machinery.

Exactness contract: ``vertex``/``slot``/``replicated``/``replicated_key``
buffers round-trip P -> P' -> P bit-exactly, and canonical ``walk`` lanes
do too; per-shard ``key`` streams are re-derived (collision-resistant via
a hash of the full old key array), so engines whose RNG is keyed per
shard resume with a fresh — tolerance-gated, not bit-exact — trajectory,
while engines with counter-based per-vertex RNG (`distributed_counts`)
resume bit-exactly on any mesh size.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint.checkpointer import unpack_json


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """Declares how one engine buffer is laid out across the mesh.

    kind  one of walk | walk_aux | vertex | slot | key | replicated_key |
          replicated (see module docstring).
    n     number of real vertices (walk/vertex/slot kinds).
    pool  per-real-vertex coupon pool sizes, length n (slot kind).
    cap   target per-shard lane capacity (walk kind). The engine passes
          the capacity its compiled programs expect; relayout grows past
          it only when the walks of one shard do not fit (never shrinks
          a declared target, never fails on skew).
    fill  empty-slot filler for walk/walk_aux/slot kinds.
    aux   names of walk_aux buffers that follow this walk buffer's
          placement (walk kind only).
    """

    kind: str
    n: Optional[int] = None
    pool: Optional[np.ndarray] = None
    cap: Optional[int] = None
    fill: int = 0
    aux: Tuple[str, ...] = ()


def derive_shard_keys(old_keys: np.ndarray, new_shards: int) -> np.ndarray:
    """Fresh independent per-shard keys from an old per-shard key array.

    The old [P, 2] uint32 array is hashed WHOLE (blake2b over its bytes +
    length), the 63-bit digest seeds a base PRNGKey, and shard p's key is
    `fold_in(base, p)`. Unlike the previous XOR-reduce (which collapsed
    every layout to a single 31-bit seed, so distinct old layouts could
    alias to identical new streams), the full-array hash separates any
    two different old key sets — including permutations of the same rows,
    which XOR could not tell apart.
    """
    data = np.ascontiguousarray(np.asarray(old_keys, dtype=np.uint32))
    h = hashlib.blake2b(data.tobytes() + np.int64(data.size).tobytes(),
                        digest_size=8).digest()
    seed = int.from_bytes(h, "little") & (2 ** 63 - 1)
    base = jax.random.PRNGKey(seed)
    return np.stack([np.asarray(jax.random.fold_in(base, p))
                     for p in range(int(new_shards))])


def _relayout_vertex(arr: np.ndarray, n: int, new_shards: int) -> np.ndarray:
    """Re-split a [P, n_loc, *rest] vertex-sharded buffer (bit-exact)."""
    old_shards, n_loc_old = arr.shape[:2]
    rest = arr.shape[2:]
    flat = arr.reshape((old_shards * n_loc_old,) + rest)[:n]
    n_loc = math.ceil(n / new_shards)
    out = np.zeros((n_loc * new_shards,) + rest, dtype=arr.dtype)
    out[:n] = flat
    return out.reshape((new_shards, n_loc) + rest)


def _slot_index(pool: np.ndarray, n: int, shards: int):
    """Flat slot index of every real coupon under a P-shard pool layout.

    Returns (flat_idx [S_total], S_loc_pad): coupon (v, j) — the j-th
    coupon of vertex v, enumerated vertex-major — lives at flat slot
    owner(v) * S_loc_pad + pstart[owner(v), v_loc] + j. This mirrors the
    placement `_run_three_phase` builds, for ANY shard count.
    """
    n_loc = math.ceil(n / shards)
    n_pad = n_loc * shards
    pool_pad = np.zeros(n_pad, dtype=np.int64)
    pool_pad[:n] = np.asarray(pool, dtype=np.int64)[:n]
    psize = pool_pad.reshape(shards, n_loc)
    pstart = np.zeros_like(psize)
    pstart[:, 1:] = np.cumsum(psize, axis=1)[:, :-1]
    S_loc_pad = max(int(psize.sum(axis=1).max()), 1)
    v = np.repeat(np.arange(n_pad), pool_pad)
    starts = np.concatenate([[0], np.cumsum(pool_pad)[:-1]])
    within = np.arange(len(v), dtype=np.int64) - np.repeat(starts, pool_pad)
    flat = (v // n_loc) * S_loc_pad + pstart.reshape(-1)[v] + within
    return flat, S_loc_pad


def _relayout_slot(arr: np.ndarray, spec: LayoutSpec, old_shards: int,
                   new_shards: int) -> np.ndarray:
    """Re-home a coupon-slot-indexed buffer (bit-exact bijection)."""
    old_idx, S_old = _slot_index(spec.pool, spec.n, old_shards)
    new_idx, S_new = _slot_index(spec.pool, spec.n, new_shards)
    if arr.shape[:2] != (old_shards, S_old):
        raise ValueError(
            f"slot buffer shape {arr.shape[:2]} does not match the "
            f"{old_shards}-shard pool layout {(old_shards, S_old)}")
    rest = arr.shape[2:]
    flat = arr.reshape((old_shards * S_old,) + rest)
    out = np.full((new_shards * S_new,) + rest, spec.fill, dtype=arr.dtype)
    out[new_idx] = flat[old_idx]
    return out.reshape((new_shards, S_new) + rest)


def _relayout_walk(primary: np.ndarray, auxes: Dict[str, np.ndarray],
                   aux_fills: Dict[str, int], spec: LayoutSpec,
                   new_shards: int) -> Dict[str, np.ndarray]:
    """Re-bucket walk lanes by new owner, canonical sorted packing.

    The per-shard cap starts from the declared target (or the old
    heuristic) and AUTO-GROWS to the most loaded shard — skewed walks can
    never overflow an elastic resume (the old code raised ValueError
    here). Aux lanes follow the primary's placement slot for slot.
    """
    old_shards, old_cap = primary.shape
    n_loc = math.ceil(spec.n / new_shards)
    flat = primary.reshape(-1)
    live = flat >= 0
    vals = flat[live]
    aux_vals = {k: a.reshape(-1)[live] for k, a in auxes.items()}
    # canonical order: by vertex, then by the aux lanes, then stable
    keys = tuple(aux_vals[k] for k in reversed(sorted(aux_vals))) + (vals,)
    order = np.lexsort(keys)
    vals = vals[order]
    aux_vals = {k: a[order] for k, a in aux_vals.items()}

    owner = np.minimum(vals // n_loc, new_shards - 1).astype(np.int64)
    counts = np.bincount(owner, minlength=new_shards)
    cap = spec.cap if spec.cap is not None else max(
        old_cap * old_shards // new_shards + new_shards * 64, 256)
    cap = max(int(cap), int(counts.max(initial=0)), 1)

    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(len(vals), dtype=np.int64) - starts[owner]
    out = {}
    new_p = np.full((new_shards, cap), spec.fill, dtype=primary.dtype)
    new_p[owner, slot] = vals
    out["__primary__"] = new_p
    for k, a in aux_vals.items():
        buf = np.full((new_shards, cap), aux_fills[k], dtype=auxes[k].dtype)
        buf[owner, slot] = a
        out[k] = buf
    return out


def relayout_arrays(arrays: Dict[str, np.ndarray],
                    specs: Dict[str, "LayoutSpec"],
                    old_shards: int, new_shards: int) -> Dict[str, np.ndarray]:
    """Schema-driven re-layout of one stage's host buffers to a new P.

    Every buffer in `arrays` must have a `LayoutSpec` in `specs`
    (walk_aux buffers are produced while their primary is processed).
    Returns a new dict shaped for `new_shards`; `old_shards == new_shards`
    is the identity for every kind except `key` (which still re-derives —
    callers skip relayout entirely on a same-size mesh).
    """
    missing = [k for k in arrays if k not in specs]
    if missing:
        raise ValueError(f"no layout schema for buffer(s) {missing}; "
                         f"schema covers {sorted(specs)}")
    out: Dict[str, np.ndarray] = {}
    for name, arr in arrays.items():
        spec = specs[name]
        arr = np.asarray(arr)
        if spec.kind == "walk":
            auxes = {a: np.asarray(arrays[a]) for a in spec.aux}
            fills = {a: specs[a].fill for a in spec.aux}
            got = _relayout_walk(arr, auxes, fills, spec, new_shards)
            out[name] = got.pop("__primary__")
            out.update(got)
        elif spec.kind == "walk_aux":
            continue                      # handled with its primary
        elif spec.kind == "vertex":
            out[name] = _relayout_vertex(arr, spec.n, new_shards)
        elif spec.kind == "slot":
            out[name] = _relayout_slot(arr, spec, old_shards, new_shards)
        elif spec.kind == "key":
            out[name] = derive_shard_keys(arr, new_shards)
        elif spec.kind == "replicated_key":
            out[name] = np.tile(np.asarray(arr)[:1], (new_shards, 1))
        elif spec.kind == "replicated":
            out[name] = arr
        else:
            raise ValueError(f"unknown layout kind {spec.kind!r} "
                             f"for buffer {name!r}")
    return out


def relayout_staged_flat(flat: Dict[str, np.ndarray], old_shards: int,
                         new_shards: int,
                         layouts: Dict[str, Dict[str, "LayoutSpec"]]
                         ) -> Dict[str, np.ndarray]:
    """Re-layout a flat `StagedState` snapshot (as written by
    `runtime.staged_to_host` through the `Checkpointer`) onto a new mesh
    size, using the schema of the stage the snapshot is tagged with."""
    stage = unpack_json(flat["stage"])
    specs = layouts.get(stage)
    if specs is None:
        raise ValueError(f"no layout schema declared for stage {stage!r}; "
                         f"schemas cover stages {sorted(layouts)}")
    arrays = {k.split("/", 1)[1]: v for k, v in flat.items()
              if k.startswith("arrays/")}
    relaid = relayout_arrays(arrays, specs, old_shards, new_shards)
    out = {f"arrays/{k}": v for k, v in relaid.items()}
    for k in flat:
        if not k.startswith("arrays/"):
            out[k] = flat[k]
    return out


# ---------------------------------------------------------------------------
# walk-engine entry point (kept for the Algorithm-1 walk-state engine)
# ---------------------------------------------------------------------------

def pagerank_state_specs(n: int, cap: int | None = None) -> Dict:
    """The Algorithm-1 walk engine's `DistState` layout schema: [P, cap]
    walk lanes, a [P, n_loc] visit shard, per-shard keys, and replicated
    scalars. Single home for the schema — `relayout_pagerank_state` and
    the CONGEST auditor's elastic-schema lint both read it."""
    return dict(
        pos=LayoutSpec(kind="walk", n=n, cap=cap, fill=-1),
        zeta=LayoutSpec(kind="vertex", n=n),
        key=LayoutSpec(kind="key"),
        round=LayoutSpec(kind="replicated"),
        dropped=LayoutSpec(kind="replicated"),
        waited=LayoutSpec(kind="replicated"),
    )


def relayout_pagerank_state(host_state: Dict, n: int, new_shards: int,
                            cap: int | None = None) -> Dict:
    """Re-layout the Algorithm-1 walk engine's `DistState` host dict
    ([P, cap] walk lanes + [P, n_loc] visit shard + per-shard keys) onto
    `new_shards`. The multiset of live walks and the per-vertex zeta are
    preserved bit-for-bit; the cap auto-grows under walk skew (an elastic
    resume never fails because one shard holds too many walks); keys are
    re-derived via `derive_shard_keys`."""
    specs = pagerank_state_specs(n, cap=cap)
    arrays = {k: np.asarray(v) for k, v in host_state.items()}
    old_shards = arrays["pos"].shape[0]
    return relayout_arrays(arrays, specs, old_shards, new_shards)
