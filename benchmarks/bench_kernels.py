"""Kernel micro-benches: interpret-mode checks + TPU roofline estimates.

Wall-times here are CPU interpret-mode (correctness path); the derived
column reports the *structural* TPU roofline estimate per kernel:
bytes touched / HBM bandwidth (all three kernels are memory-bound gathers
or one-hot reductions at our sizes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.graphs import barabasi_albert
from repro.kernels.histogram import histogram
from repro.kernels.segment_spmv import segment_spmv
from repro.kernels.walk_step import walk_step

HBM_BW = 819e9


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    g = barabasi_albert(1024, 4, seed=5)

    W, n = 65536, 1024
    ids = jax.random.randint(key, (W,), 0, n)
    t0 = time.perf_counter()
    jax.block_until_ready(histogram(ids, n))
    dt = time.perf_counter() - t0
    bytes_touched = W * 4 + n * 4
    rows.append(("histogram_64k", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))

    E = g.m
    val = jax.random.normal(key, (E,))
    t0 = time.perf_counter()
    jax.block_until_ready(segment_spmv(val, g.col_idx, g.n))
    dt = time.perf_counter() - t0
    bytes_touched = E * 8 + g.n * 4
    rows.append((f"segment_spmv_E{E}", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))

    pos = jax.random.randint(key, (W,), 0, g.n)
    alive = jnp.ones((W,), bool)
    ut = jax.random.uniform(key, (W,))
    ue = jax.random.uniform(key, (W,))
    t0 = time.perf_counter()
    jax.block_until_ready(walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                    g.out_deg, eps=0.2))
    dt = time.perf_counter() - t0
    bytes_touched = W * (4 * 5) + (g.n * 8 + g.m * 4)
    rows.append((f"walk_step_64k", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
