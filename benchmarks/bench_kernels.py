"""Kernel micro-benches: interpret-mode checks + TPU roofline estimates.

Wall-times here are CPU interpret-mode (correctness path); the derived
column reports the *structural* TPU roofline estimate per kernel:
bytes touched / HBM bandwidth (all four kernels are memory-bound gathers
or one-hot reductions at our sizes).

`--smoke` (the CI leg) runs a reduced-size sweep and, for the
multinomial_rows kernel, additionally asserts the Pallas path is
bit-identical to the jnp ref — a cheap cross-check that runs in every
(devices, pallas) cell of the CI matrix.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import barabasi_albert
from repro.kernels.multinomial_rows import multinomial_rows
from repro.kernels.multinomial_rows.ref import multinomial_rows_ref
from repro.kernels.walk_step import walk_step
from repro.kernels.histogram import histogram
from repro.kernels.segment_spmv import segment_spmv

HBM_BW = 819e9


def run(smoke: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    g = barabasi_albert(1024, 4, seed=5)

    W, n = (8192, 256) if smoke else (65536, 1024)
    ids = jax.random.randint(key, (W,), 0, n)
    t0 = time.perf_counter()
    jax.block_until_ready(histogram(ids, n))
    dt = time.perf_counter() - t0
    bytes_touched = W * 4 + n * 4
    rows.append((f"histogram_{W // 1024}k", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))

    E = g.m
    val = jax.random.normal(key, (E,))
    t0 = time.perf_counter()
    jax.block_until_ready(segment_spmv(val, g.col_idx, g.n))
    dt = time.perf_counter() - t0
    bytes_touched = E * 8 + g.n * 4
    rows.append((f"segment_spmv_E{E}", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))

    pos = jax.random.randint(key, (W,), 0, g.n)
    alive = jnp.ones((W,), bool)
    ut = jax.random.uniform(key, (W,))
    ue = jax.random.uniform(key, (W,))
    t0 = time.perf_counter()
    jax.block_until_ready(walk_step(pos, alive, ut, ue, g.row_ptr, g.col_idx,
                                    g.out_deg, eps=0.2))
    dt = time.perf_counter() - t0
    bytes_touched = W * (4 * 5) + (g.n * 8 + g.m * 4)
    rows.append((f"walk_step_{W // 1024}k", dt * 1e6,
                 f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"))

    # fused aggregate-multinomial sampler (ref vs Pallas, same draws)
    R, width = (2048, 8) if smoke else (16384, 16)
    k1, k2 = jax.random.split(key)
    counts = jax.random.randint(k1, (R,), 0, 5000)
    deg = jax.random.randint(k2, (R,), 0, width + 1)
    rid = jnp.arange(R, dtype=jnp.int32)
    kw = jnp.asarray(np.array([7, 13], np.uint32))
    t0 = time.perf_counter()
    ref = jax.block_until_ready(multinomial_rows_ref(
        counts, deg, rid, kw, eps=0.2, width=width))
    dt_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    pal = jax.block_until_ready(multinomial_rows(
        counts, deg, rid, kw, eps=0.2, width=width))
    dt_pal = time.perf_counter() - t0
    bytes_touched = R * (4 * 3) + R * (width + 1) * 4
    roofline = f"tpu_roofline_us={bytes_touched / HBM_BW * 1e6:.2f}"
    rows.append((f"multinomial_rows_ref_R{R}", dt_ref * 1e6, roofline))
    rows.append((f"multinomial_rows_pallas_R{R}", dt_pal * 1e6, roofline))
    if smoke:
        # CI gate: the kernel must be bit-identical to the jnp oracle
        assert np.array_equal(np.asarray(ref), np.asarray(pal)), \
            "multinomial_rows pallas/ref mismatch"
        assert np.array_equal(np.asarray(ref).sum(axis=1),
                              np.asarray(counts)), \
            "multinomial_rows conservation leak"
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + hard bit-parity assertions "
                         "(the CI device-matrix leg)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
