"""Accuracy vs K (Avrachenkov: 'one iteration is sufficient').

L1 / Linf / top-k overlap of pi_tilde vs power-iteration reference as the
number of walks per node K grows; both algorithms and the directed/LOCAL
variant.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (directed_local_pagerank, improved_pagerank, l1_error,
                        linf_error, normalized, power_iteration,
                        simple_pagerank, topk_overlap, walks_per_node_for)
from repro.graphs import barabasi_albert, directed_web


def run(n=256, eps=0.2, Ks=(5, 20, 80, 320)):
    g = barabasi_albert(n, 3, seed=2)
    gd = directed_web(n, 6.0, seed=2)
    pi_ref, _, _ = power_iteration(g, eps)
    pi_dref, _, _ = power_iteration(gd, eps)
    rows = []
    for K in Ks:
        t0 = time.time()
        rs = simple_pagerank(g, eps, walks_per_node=K,
                             key=jax.random.PRNGKey(K))
        dt_s = time.time() - t0
        ri = improved_pagerank(g, eps, walks_per_node=K,
                               key=jax.random.PRNGKey(K + 1))
        rd = directed_local_pagerank(gd, eps, walks_per_node=K,
                                     key=jax.random.PRNGKey(K + 2))
        rows.append(dict(
            K=K,
            simple_l1=l1_error(normalized(rs.pi), pi_ref),
            improved_l1=l1_error(normalized(ri.pi), pi_ref),
            directed_l1=l1_error(normalized(rd.pi), pi_dref),
            simple_linf=linf_error(normalized(rs.pi), pi_ref),
            top10=topk_overlap(rs.pi, np.asarray(pi_ref), 10),
            us=dt_s * 1e6,
        ))
    K_paper = walks_per_node_for(n, eps)
    r_paper = simple_pagerank(g, eps, walks_per_node=K_paper,
                              key=jax.random.PRNGKey(0))
    rows.append(dict(K=K_paper, simple_l1=l1_error(normalized(r_paper.pi),
                                                   pi_ref),
                     improved_l1=float("nan"), directed_l1=float("nan"),
                     simple_linf=linf_error(normalized(r_paper.pi), pi_ref),
                     top10=topk_overlap(r_paper.pi, np.asarray(pi_ref), 10),
                     us=0, paper_K=True))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        tag = "paperK_" if r.get("paper_K") else ""
        print(f"accuracy_{tag}K{r['K']},{r['us']:.0f},"
              f"simple_l1={r['simple_l1']:.4f};improved_l1={r['improved_l1']:.4f};"
              f"directed_l1={r['directed_l1']:.4f};top10={r['top10']:.2f}")
    return rows


if __name__ == "__main__":
    main()
