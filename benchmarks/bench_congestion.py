"""Lemma 1 / Lemma 3 validation: per-edge message bits stay polylog.

Measures max bits per edge per round as the number of parallel walks grows
100x — the count-based message structure keeps payloads logarithmic
(counts, never walk identities).
"""
from __future__ import annotations

import math
import time

import jax

from repro.core import simple_pagerank
from repro.core.accounting import default_bandwidth
from repro.graphs import barabasi_albert


def run(n=256, eps=0.2, Ks=(10, 100, 1000)):
    g = barabasi_albert(n, 3, seed=3)
    B = default_bandwidth(n)
    rows = []
    for K in Ks:
        t0 = time.time()
        res = simple_pagerank(g, eps, walks_per_node=K,
                              key=jax.random.PRNGKey(K), traced=True)
        rows.append(dict(
            K=K, walks=n * K,
            max_bits=res.report.max_bits_per_edge_per_round,
            bandwidth_B=B,
            logical=res.report.logical_rounds,
            congest=res.report.congest_rounds,
            log2_walks=math.log2(n * K),
            us=(time.time() - t0) * 1e6,
        ))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"congestion_K{r['K']},{r['us']:.0f},"
              f"max_bits_per_edge={r['max_bits']};B={r['bandwidth_B']};"
              f"log2_total_walks={r['log2_walks']:.1f};"
              f"congest_rounds={r['congest']}")
    return rows


if __name__ == "__main__":
    main()
