"""Engine throughput: counts vs walk-array vs Pallas-fused vs power-iter.

Walks/second (steady-state, jit-compiled) for the faithful count engine and
the TPU-native walk engine; power-iteration L1-convergence wall time as the
classical baseline the paper argues against.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import engine_walks, power_iteration, simple_pagerank
from repro.core.engine_counts import init_state as counts_init, _step as counts_step
from repro.core.graph import padded_adjacency
from repro.graphs import barabasi_albert


def _time(fn, iters=5):
    fn()  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(n=512, eps=0.2, K=100):
    g = barabasi_albert(n, 3, seed=4)
    W = n * K
    rows = []

    # walk-array engine, one superstep
    state = engine_walks.init_state(g, K, jax.random.PRNGKey(0))
    step = jax.jit(lambda s: engine_walks._step_core(
        g.row_ptr, g.col_idx, g.out_deg, eps, s)[0])
    dt = _time(lambda: jax.block_until_ready(step(state).zeta))
    rows.append(dict(name="walk_array_step", us=dt * 1e6,
                     walks_per_s=W / dt))

    # walk-array engine with Pallas fused step + histogram
    step_p = jax.jit(lambda s: engine_walks._step_core(
        g.row_ptr, g.col_idx, g.out_deg, eps, s, use_pallas=True)[0])
    dt = _time(lambda: jax.block_until_ready(step_p(state).zeta), iters=2)
    rows.append(dict(name="walk_array_step_pallas_interp", us=dt * 1e6,
                     walks_per_s=W / dt))

    # count engine, one round
    nbr, _ = padded_adjacency(g)
    cstate = counts_init(g, K, jax.random.PRNGKey(0))
    dt = _time(lambda: jax.block_until_ready(
        counts_step(nbr, g.out_deg, cstate, eps, g.n, int(nbr.shape[1]))[0]
        .counts))
    rows.append(dict(name="count_engine_step", us=dt * 1e6,
                     walks_per_s=W / dt))

    # full solves
    t0 = time.perf_counter()
    simple_pagerank(g, eps, walks_per_node=K, key=jax.random.PRNGKey(1))
    rows.append(dict(name="simple_pagerank_full", us=(time.perf_counter() - t0) * 1e6,
                     walks_per_s=0))
    t0 = time.perf_counter()
    power_iteration(g, eps, tol=1e-7)
    rows.append(dict(name="power_iteration_full", us=(time.perf_counter() - t0) * 1e6,
                     walks_per_s=0))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.0f},walks_per_s={r['walks_per_s']:.3e}")
    return rows


if __name__ == "__main__":
    main()
