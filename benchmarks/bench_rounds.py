"""Theorem 1 & 2 validation: round complexity vs n and eps.

SIMPLE-PAGERANK: O(log n / eps) CONGEST rounds.
IMPROVED-PAGERANK: O(sqrt(log n) / eps) CONGEST rounds.
Reported: logical + CONGEST(B) rounds per (n, eps) with fitted scaling.
"""
from __future__ import annotations

import math
import time

import jax
import numpy as np

from repro.core import improved_pagerank, simple_pagerank
from repro.graphs import erdos_renyi


def run(sizes=(64, 128, 256, 512), eps_list=(0.4, 0.2, 0.1), K=40):
    rows = []
    for n in sizes:
        g = erdos_renyi(n, 6.0, seed=1)
        for eps in eps_list:
            t0 = time.time()
            rs = simple_pagerank(g, eps, walks_per_node=K,
                                 key=jax.random.PRNGKey(1), traced=True)
            t_simple = time.time() - t0
            t0 = time.time()
            ri = improved_pagerank(g, eps, walks_per_node=K,
                                   key=jax.random.PRNGKey(2))
            t_improved = time.time() - t0
            rows.append(dict(
                n=n, eps=eps,
                simple_logical=rs.logical_rounds,
                simple_congest=rs.report.congest_rounds,
                improved_congest=ri.report.congest_rounds,
                improved_stitches=ri.stitch_iterations,
                lam=ri.lam,
                ratio=rs.report.congest_rounds
                / max(ri.report.congest_rounds, 1),
                us_simple=t_simple * 1e6, us_improved=t_improved * 1e6,
            ))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"rounds_simple_n{r['n']}_eps{r['eps']},{r['us_simple']:.0f},"
              f"congest_rounds={r['simple_congest']}")
        print(f"rounds_improved_n{r['n']}_eps{r['eps']},{r['us_improved']:.0f},"
              f"congest_rounds={r['improved_congest']};"
              f"speedup={r['ratio']:.2f}x")
    # scaling fits: rounds vs 1/eps at fixed n (Theorem 1: linear in 1/eps)
    n = max(r["n"] for r in rows)
    sub = [r for r in rows if r["n"] == n]
    inv_eps = np.array([1 / r["eps"] for r in sub])
    simple = np.array([r["simple_congest"] for r in sub], float)
    slope = np.polyfit(inv_eps, simple, 1)[0]
    print(f"fit_simple_rounds_vs_inv_eps_n{n},0,slope={slope:.2f}")
    return rows


if __name__ == "__main__":
    main()
