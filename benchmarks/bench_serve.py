"""PPR query serving under Poisson traffic: qps and latency percentiles.

Drives `repro.serve.PPRService` (resident sharded graph + batched
multi-source walk engine + continuous-batching admission) with an open-
loop Poisson arrival process and measures wall-clock request latency.
Traffic is a hot/cold mix: a small pool of hot queries recurs (exercising
the LRU result cache) while the rest are unique cold queries that must be
computed. Subprocess per shard count — device count is process-global.

Emitted columns per shard count: achieved queries/sec over the measured
window, cold-path p50/p99 latency (requests that ran walks), warm-path
p50/p99 latency (requests answered from the cache at submit time), cache
hits, supersteps, and the drop counters.

`--json [PATH]` writes the raw rows to a machine-readable artifact
(default BENCH_serve.json). Artifact schema (per row): `shards`, graph
size `n`, `walks_per_query`, `slots`, offered load `target_qps`, request
counts (`requests`, `completed`, `cache_hits`), achieved `qps`, latency
percentiles in microseconds split by path — `cold_p50_us`/`cold_p99_us`
(computed end-to-end: queueing + walk supersteps + extraction) vs
`warm_p50_us`/`warm_p99_us` (cache hit at submit; no walk ever runs) —
plus `supersteps` and the exactness counters `dropped`, `admit_dropped`,
`rejected`.

Two caveats for reading the numbers: (1) warm vs cold are DIFFERENT
code paths, not a compile effect — one engine warmup query (excluded
from the window) pays all XLA compilation before measurement starts;
(2) the P "devices" are host-serialized virtual shards sharing one CPU,
so per-shard superstep compute runs serialized and the all_to_all is
priced at zero — latencies measure the batching/scheduling layer's
behavior honestly, but absolute qps does NOT model a real multi-host
deployment's network or parallel speedup.

A serving benchmark that drops or rejects queries is not measuring the
advertised exact path, so the process exits nonzero if ANY row reports a
nonzero `dropped`, `admit_dropped`, or `rejected` counter — mirroring
the bench_distributed drop gate. `--smoke` shrinks the graph, walk
count, and request count for the CI leg; the gate applies there too.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import json, time
import numpy as np
import jax
from repro.graphs import barabasi_albert
from repro.serve import PPRService

SMOKE = {smoke}
n = 64 if SMOKE else 256
walks_per_query = 600 if SMOKE else 6000
slots = 4 if SMOKE else 8
n_req = 16 if SMOKE else 64
hot_pool, hot_frac = 4, 0.5

g = barabasi_albert(n, 3, seed=3)
svc = PPRService(g, 0.25, slots=slots, walks_per_query=walks_per_query,
                 cache_entries=128, key=jax.random.PRNGKey(7))

# warmup 1 pays all XLA compilation (admit/superstep/extract programs);
# warmup 2 times one steady-state query drain, which calibrates the
# offered load to ~the service's drain rate so the measured window spans
# several completion waves (hot repeats arriving after their first
# compute finishes hit the cache — a far-oversubscribed rate would
# front-load every arrival and never see a warm hit). Both warmup
# sources sit outside the traffic pool so neither seeds a cache hit.
svc.submit([g.n - 1])
svc.drain()
t0 = time.monotonic()
svc.submit([g.n - 2])
svc.drain()
t_query = time.monotonic() - t0
svc.reset_stats()
target_qps = slots / max(t_query, 1e-3)

rng = np.random.default_rng(11)
arrivals = np.cumsum(rng.exponential(1.0 / target_qps, size=n_req))
hot = [sorted(rng.choice(g.n - 2, size=2, replace=False).tolist())
       for _ in range(hot_pool)]
queries = [hot[int(rng.integers(hot_pool))] if rng.random() < hot_frac
           else sorted(rng.choice(g.n - 2, size=2,
                                  replace=False).tolist())
           for _ in range(n_req)]

t0 = time.monotonic()
reqs, i = [], 0
while i < n_req or svc.busy:
    now = time.monotonic() - t0
    while i < n_req and arrivals[i] <= now:
        reqs.append(svc.submit(queries[i]))
        i += 1
    if svc.busy:
        svc.step()
    elif i < n_req:
        time.sleep(min(arrivals[i] - now, 0.005))
window = time.monotonic() - t0

lat = lambda rs: sorted((r.latency for r in rs), key=float)
pct = lambda xs, q: (float(np.percentile(xs, q)) * 1e6) if xs else 0.0
cold = lat([r for r in reqs if r.done and not r.cached and not r.rejected])
warm = lat([r for r in reqs if r.cached])
s = svc.stats
print(json.dumps(dict(
    shards=jax.device_count(), n=n, walks_per_query=walks_per_query,
    slots=slots, target_qps=target_qps, requests=n_req,
    completed=s.completed, cache_hits=s.cache_hits,
    qps=n_req / window,
    cold_p50_us=pct(cold, 50), cold_p99_us=pct(cold, 99),
    warm_p50_us=pct(warm, 50), warm_p99_us=pct(warm, 99),
    supersteps=s.supersteps, max_active=s.max_active_queries,
    a2a_bytes=s.a2a_bytes, dropped=s.dropped_walks,
    admit_dropped=s.admit_dropped, rejected=s.rejected)))
"""


def run(shard_counts=(1, 8), smoke=False):
    rows = []
    for p in shard_counts:
        env = dict(os.environ)  # REPRO_USE_PALLAS etc. propagate
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = SRC
        res = subprocess.run(
            [sys.executable, "-c", _CODE.format(smoke=smoke)], env=env,
            capture_output=True, text=True, timeout=3600)
        if res.returncode != 0:
            rows.append(dict(shards=p, error=res.stderr[-200:]))
            continue
        rows.append(json.loads(res.stdout.strip().splitlines()[-1]))
    return rows


def report(rows):
    print("name,us_per_call,derived")
    for r in rows:
        if "error" in r:
            print(f"serve_shards{r['shards']},0,ERROR={r['error'][:80]}")
            continue
        print(f"serve_ppr_P{r['shards']},{r['cold_p50_us']:.0f},"
              f"qps={r['qps']:.1f};cold_p99_us={r['cold_p99_us']:.0f};"
              f"warm_p50_us={r['warm_p50_us']:.0f};"
              f"warm_p99_us={r['warm_p99_us']:.0f};"
              f"cache_hits={r['cache_hits']}/{r['requests']};"
              f"supersteps={r['supersteps']};"
              f"max_active={r['max_active']};"
              f"dropped={r['dropped']};"
              f"admit_dropped={r['admit_dropped']};"
              f"rejected={r['rejected']}")


def check_dropped(rows):
    """Collect (row-label, counter, value) for every nonzero counter that
    would make the run lossy: dropped walks, admission overflow, or
    rejected queries (the bench offers no max_pending, so ANY rejection
    is a bug, not backpressure)."""
    bad = []
    for r in rows:
        if "error" in r:
            bad.append((f"shards={r['shards']}", "error", r["error"]))
            continue
        label = f"P{r['shards']}"
        for field in ("dropped", "admit_dropped", "rejected"):
            if r.get(field):
                bad.append((label, field, r[field]))
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="also write the raw rows (qps, latency "
                         "percentiles, drop counters) to a JSON artifact")
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced graph/walks/request count for CI")
    args = ap.parse_args(argv)
    rows = run(tuple(args.shards), smoke=args.smoke)
    report(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(schema=1, bench="ppr_serving",
                           smoke=args.smoke, shard_counts=args.shards,
                           rows=rows), f, indent=2)
        print(f"[bench] wrote {args.json} ({len(rows)} rows)")
    bad = check_dropped(rows)
    if bad:
        for label, field, value in bad:
            print(f"[bench] DROPPED: {label} {field}={value}",
                  file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    main()
