"""Distributed engine scaling: Algorithm 1 (walk-routing and
count-aggregated wire) vs Algorithm 2 (sharded IMPROVED-PAGERANK) vs
Section 5 (sharded directed/LOCAL).

Reproduces the §Perf hillclimb measurements: all_to_all payload and round
counts to full termination for all four engines at 2/8 shards (subprocess
per shard count — device count is process-global). The three undirected
engines run on an Erdos–Renyi graph at two walk counts; the Section-5
engine runs on a power-law directed web at K=50 only (its uniform LOCAL
pools scale ~K*log^2 n, so larger K mostly benchmarks buffer sorts), next
to an Algorithm-1 walk run on the SAME directed graph for the directed
round-speedup column. Emitted columns per engine: wall time, total rounds,
phase-round breakdown (3-phase engines: p1/report/p2/p3/tail), and wire
volume (total all_to_all payload bytes, by phase for the 3-phase engines).

Each engine is invoked twice with identical shapes and a different PRNG
key: the FIRST call pays XLA compilation of every superstep program (the
3-phase engines compile three stage programs to Algorithm 1's one; the
step makers are memoized, so the compile is once per process, not per
call), the SECOND reuses the jit cache and measures the steady-state
run. The headline `*_us` column is the steady-state time; `*_cold_us`
keeps the compile-inclusive first call honest next to it.

Caveat on reading the wall-clock columns: the P "devices" are virtual —
they share one CPU, so each round's per-shard compute runs serialized
and wall time rewards low TOTAL compute, not low round count. That
flatters the count-state Algorithm-1 engine (an O(n_loc * max_deg)
histogram push per round) over the 3-phase engines (per-coupon pool
tables), and prices the network at zero. The round and wire columns are
the paper-relevant measures; the wall-clock columns are honest about
what this simulation actually pays.

Power-law rows (8-shard leg only): the count-aggregated engines rerun on
a hub-heavy `barabasi_albert_hub` graph (forced hub of degree ~n/4 next
to a median degree of ~3) twice — with the degree-bucketed aggregate
sampler (the default) and with `bucketed=False` (the pre-bucketing
single-bucket layout, same code path) — and the row reports both warm
wall times AND both engines' `sampler_us` telemetry (wall microseconds
inside the sample program alone), plus the per-bucket occupancy. The
draws are bit-identical across the two layouts (counter RNG), so the
`sampler_speedup` column isolates exactly the O(max_deg) -> O(bucket
width) chain-scan win the bucketing exists for; on hub-heavy graphs it
should be >= 2x.

`--json [PATH]` additionally writes the raw rows to a machine-readable
artifact (default BENCH_distributed.json) so the perf trajectory can be
tracked across PRs.

Every row carries each engine's drop counter (`*_dropped`; the counts
engine reports lane `overflow`). A benchmark that drops walks is not
measuring the algorithm, so the process exits nonzero if ANY engine
reports a nonzero drop count — wire/round numbers from a lossy run must
never land in the artifact unflagged.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import json, time, jax
from repro.core.distributed import distributed_pagerank
from repro.core.distributed_counts import distributed_pagerank_counts
from repro.core.distributed_directed import distributed_directed_pagerank
from repro.core.distributed_improved import distributed_improved_pagerank
from repro.graphs import barabasi_albert_hub, directed_web, erdos_renyi

def phases(r):
    return dict(p1=r.phase1_rounds, report=r.report_rounds,
                p2=r.phase2_rounds, p3=r.phase3_rounds, tail=r.tail_rounds)

def coupons(r):
    return dict(created=r.coupons_created, used=r.coupons_used,
                exhausted=r.exhausted_walks)

def timed(fn, seed):
    # cold call compiles every superstep program; the warm call (same
    # shapes, fresh key) reuses the jit cache = steady-state run time
    t0 = time.time(); fn(jax.random.PRNGKey(seed)); cold = time.time() - t0
    t0 = time.time(); r = fn(jax.random.PRNGKey(seed + 1))
    return r, (time.time() - t0) * 1e6, cold * 1e6

g = erdos_renyi(200, 6.0, seed=3)
out = []
for K in (100, 400):
    rw, tw, cw = timed(lambda k: distributed_pagerank(g, 0.2, K, k), 10)
    rc, tc, cc = timed(
        lambda k: distributed_pagerank_counts(g, 0.2, K, k), 20)
    ri, ti, ci = timed(
        lambda k: distributed_improved_pagerank(g, 0.2, K, k), 30)
    out.append(dict(K=K, shards=rw.shards,
                    walk_a2a=rw.a2a_bytes_total, walk_rounds=rw.rounds,
                    walk_us=tw, walk_cold_us=cw, walk_dropped=rw.dropped,
                    count_a2a=rc.a2a_bytes_total, count_rounds=rc.rounds,
                    count_us=tc, count_cold_us=cc, count_dropped=rc.overflow,
                    imp_a2a=ri.a2a_bytes_total, imp_rounds=ri.rounds,
                    imp_us=ti, imp_cold_us=ci, imp_dropped=ri.dropped,
                    imp_phases=phases(ri), imp_wire=ri.a2a_bytes_by_phase,
                    imp_coupons=coupons(ri)))

# Section 5 on a directed power-law web, vs Algorithm 1 on the same graph
# (the walk engine gets the worst-case W buffer: directed hubs overflow
# the 2*W/P CONGEST sizing)
gd = directed_web(200, 6.0, seed=3)
K = 50
rdw, tdw, cdw = timed(
    lambda k: distributed_pagerank(gd, 0.2, K, k, cap=gd.n * K + 8 * 64),
    40)
rd, td, cd = timed(
    lambda k: distributed_directed_pagerank(gd, 0.2, K, k), 50)
out.append(dict(K=K, shards=rd.shards, directed=True,
                walk_a2a=rdw.a2a_bytes_total, walk_rounds=rdw.rounds,
                walk_us=tdw, walk_cold_us=cdw, walk_dropped=rdw.dropped,
                dir_a2a=rd.a2a_bytes_total, dir_rounds=rd.rounds,
                dir_us=td, dir_cold_us=cd,
                dir_phases=phases(rd), dir_wire=rd.a2a_bytes_by_phase,
                dir_coupons=coupons(rd),
                dir_budget=rd.uniform_budget, dir_dropped=rd.dropped))

# Power-law hub stress (8-shard leg): bucketed vs flat sampler layout.
# Same keys -> bit-identical trajectories, so the sampler_us delta is
# pure layout (O(max_deg) chain scan vs O(bucket width)).
if jax.device_count() >= 8:
    gh = barabasi_albert_hub(1024, 3, seed=7)
    K = 100
    rb, tb, cb = timed(
        lambda k: distributed_pagerank_counts(gh, 0.2, K, k), 60)
    rf, tf, cf = timed(
        lambda k: distributed_pagerank_counts(gh, 0.2, K, k,
                                              bucketed=False), 60)
    rib, tib, cib = timed(
        lambda k: distributed_improved_pagerank(gh, 0.2, K, k), 80)
    rif, tif, cif = timed(
        lambda k: distributed_improved_pagerank(gh, 0.2, K, k,
                                                bucketed=False), 80)
    out.append(dict(
        K=K, shards=rb.shards, powerlaw=True, n=gh.n,
        max_deg=int(max(gh.out_deg)),
        count_us=tb, count_cold_us=cb, count_flat_us=tf,
        count_sampler_us=rb.sampler_us,
        count_flat_sampler_us=rf.sampler_us,
        count_rounds=rb.rounds, count_occupancy=list(rb.occupancy),
        count_dropped=rb.overflow + rf.overflow
        + abs(rb.residual) + abs(rf.residual),
        imp_us=tib, imp_cold_us=cib, imp_flat_us=tif,
        imp_sampler_us=rib.sampler_us,
        imp_flat_sampler_us=rif.sampler_us,
        imp_rounds=rib.rounds, imp_occupancy=list(rib.p1_occupancy),
        imp_dropped=rib.dropped + rif.dropped
        + abs(rib.residual) + abs(rif.residual)))
print(json.dumps(out))
"""


def run(shard_counts=(2, 8)):
    rows = []
    for p in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = SRC
        res = subprocess.run([sys.executable, "-c", _CODE], env=env,
                             capture_output=True, text=True, timeout=3600)
        if res.returncode != 0:
            rows.append(dict(shards=p, error=res.stderr[-200:]))
            continue
        rows.extend(json.loads(res.stdout.strip().splitlines()[-1]))
    return rows


def _phase_str(ph):
    return "/".join(f"{n}={ph[n]}" for n in
                    ("p1", "report", "p2", "p3", "tail"))


def _wire_str(wire):
    return ";".join(f"{n}_bytes={v}" for n, v in sorted(wire.items()))


def report(rows):
    print("name,us_per_call,derived")
    for r in rows:
        if "error" in r:
            print(f"dist_shards{r['shards']},0,ERROR={r['error'][:80]}")
            continue
        p, k = r["shards"], r["K"]
        if r.get("powerlaw"):
            c_spd = (r["count_flat_sampler_us"]
                     / max(r["count_sampler_us"], 1.0))
            i_spd = (r["imp_flat_sampler_us"]
                     / max(r["imp_sampler_us"], 1.0))
            print(f"dist_hubcount_P{p}_K{k},{r['count_us']:.0f},"
                  f"cold_us={r['count_cold_us']:.0f};"
                  f"flat_us={r['count_flat_us']:.0f};"
                  f"rounds={r['count_rounds']};"
                  f"sampler_us={r['count_sampler_us']:.0f};"
                  f"flat_sampler_us={r['count_flat_sampler_us']:.0f};"
                  f"sampler_speedup={c_spd:.2f}x;"
                  f"max_deg={r['max_deg']};"
                  f"occupancy={r['count_occupancy']};"
                  f"dropped={r['count_dropped']}")
            print(f"dist_hubimproved_P{p}_K{k},{r['imp_us']:.0f},"
                  f"cold_us={r['imp_cold_us']:.0f};"
                  f"flat_us={r['imp_flat_us']:.0f};"
                  f"rounds={r['imp_rounds']};"
                  f"sampler_us={r['imp_sampler_us']:.0f};"
                  f"flat_sampler_us={r['imp_flat_sampler_us']:.0f};"
                  f"sampler_speedup={i_spd:.2f}x;"
                  f"occupancy={r['imp_occupancy']};"
                  f"dropped={r['imp_dropped']}")
            continue
        if r.get("directed"):
            cp = r["dir_coupons"]
            print(f"dist_dirwalk_P{p}_K{k},{r['walk_us']:.0f},"
                  f"cold_us={r['walk_cold_us']:.0f};"
                  f"rounds={r['walk_rounds']};a2a_bytes={r['walk_a2a']}")
            print(f"dist_directed_P{p}_K{k},{r['dir_us']:.0f},"
                  f"cold_us={r['dir_cold_us']:.0f};"
                  f"rounds={r['dir_rounds']};"
                  f"phases={_phase_str(r['dir_phases'])};"
                  f"{_wire_str(r['dir_wire'])};"
                  f"coupons_used={cp['used']}/{cp['created']};"
                  f"exhausted={cp['exhausted']};budget={r['dir_budget']};"
                  f"dropped={r['dir_dropped']};round_speedup="
                  f"{r['walk_rounds'] / max(r['dir_rounds'], 1):.2f}x")
            continue
        print(f"dist_walk_P{p}_K{k},{r['walk_us']:.0f},"
              f"cold_us={r['walk_cold_us']:.0f};"
              f"rounds={r['walk_rounds']};a2a_bytes={r['walk_a2a']}")
        print(f"dist_count_P{p}_K{k},{r['count_us']:.0f},"
              f"cold_us={r['count_cold_us']:.0f};"
              f"rounds={r['count_rounds']};a2a_bytes={r['count_a2a']};"
              f"reduction={r['walk_a2a']/max(r['count_a2a'],1):.1f}x")
        cp = r["imp_coupons"]
        print(f"dist_improved_P{p}_K{k},{r['imp_us']:.0f},"
              f"cold_us={r['imp_cold_us']:.0f};"
              f"rounds={r['imp_rounds']};"
              f"phases={_phase_str(r['imp_phases'])};"
              f"{_wire_str(r['imp_wire'])};"
              f"coupons_used={cp['used']}/{cp['created']};"
              f"exhausted={cp['exhausted']};"
              f"round_speedup={r['walk_rounds']/max(r['imp_rounds'],1):.2f}x;"
              f"us_speedup_vs_count={r['count_us']/max(r['imp_us'],1):.2f}x")


def check_dropped(rows):
    """Collect (row-label, counter, value) for every nonzero drop count."""
    bad = []
    for r in rows:
        if "error" in r:
            bad.append((f"shards={r['shards']}", "error", r["error"]))
            continue
        label = f"P{r['shards']}_K{r['K']}"
        for field in ("walk_dropped", "count_dropped", "imp_dropped",
                      "dir_dropped"):
            if r.get(field):
                bad.append((label, field, r[field]))
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_distributed.json",
                    default=None, metavar="PATH",
                    help="also write the raw rows (rounds, wire volume, "
                         "wall time per engine) to a JSON artifact")
    ap.add_argument("--shards", type=int, nargs="+", default=[2, 8])
    args = ap.parse_args(argv)
    rows = run(tuple(args.shards))
    report(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(schema=1, bench="distributed_engines",
                           shard_counts=args.shards, rows=rows), f, indent=2)
        print(f"[bench] wrote {args.json} ({len(rows)} rows)")
    bad = check_dropped(rows)
    if bad:
        for label, field, value in bad:
            print(f"[bench] DROPPED: {label} {field}={value}",
                  file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    main()
