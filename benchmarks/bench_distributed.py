"""Distributed engine scaling: walk-routing vs count-aggregated wire.

Reproduces the §Perf hillclimb measurements: all_to_all payload to full
termination for both engines at 2/4/8 shards and two walk counts
(subprocess per shard count — device count is process-global).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import json, time, jax
from repro.core.distributed import distributed_pagerank
from repro.core.distributed_counts import distributed_pagerank_counts
from repro.graphs import erdos_renyi
g = erdos_renyi(200, 6.0, seed=3)
out = []
for K in (100, 400):
    t0 = time.time()
    rw = distributed_pagerank(g, 0.2, K, jax.random.PRNGKey(0))
    tw = time.time() - t0
    t0 = time.time()
    rc = distributed_pagerank_counts(g, 0.2, K, jax.random.PRNGKey(1))
    tc = time.time() - t0
    out.append(dict(K=K, walk_a2a=rw.a2a_bytes_total,
                    count_a2a=rc.a2a_bytes_total,
                    walk_us=tw * 1e6, count_us=tc * 1e6,
                    shards=rw.shards))
print(json.dumps(out))
"""


def run(shard_counts=(2, 8)):
    rows = []
    for p in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = SRC
        res = subprocess.run([sys.executable, "-c", _CODE], env=env,
                             capture_output=True, text=True, timeout=1200)
        if res.returncode != 0:
            rows.append(dict(shards=p, error=res.stderr[-200:]))
            continue
        rows.extend(json.loads(res.stdout.strip().splitlines()[-1]))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        if "error" in r:
            print(f"dist_shards{r['shards']},0,ERROR={r['error'][:80]}")
            continue
        print(f"dist_walk_P{r['shards']}_K{r['K']},{r['walk_us']:.0f},"
              f"a2a_bytes={r['walk_a2a']}")
        print(f"dist_count_P{r['shards']}_K{r['K']},{r['count_us']:.0f},"
              f"a2a_bytes={r['count_a2a']};"
              f"reduction={r['walk_a2a']/max(r['count_a2a'],1):.1f}x")
    return rows


if __name__ == "__main__":
    main()
