"""Distributed engine scaling: Algorithm 1 (walk-routing and
count-aggregated wire) vs Algorithm 2 (sharded IMPROVED-PAGERANK) vs
Section 5 (sharded directed/LOCAL).

Reproduces the §Perf hillclimb measurements: all_to_all payload and round
counts to full termination for all four engines at 2/8 shards (subprocess
per shard count — device count is process-global). The three undirected
engines run on an Erdos–Renyi graph at two walk counts; the Section-5
engine runs on a power-law directed web at K=50 only (its uniform LOCAL
pools scale ~K*log^2 n, so larger K mostly benchmarks buffer sorts), next
to an Algorithm-1 walk run on the SAME directed graph for the directed
round-speedup column. Emitted columns per engine: wall time, total rounds,
phase-round breakdown (3-phase engines: p1/report/p2/p3/tail), and wire
volume (total all_to_all payload bytes, by phase for the 3-phase engines).

`--json [PATH]` additionally writes the raw rows to a machine-readable
artifact (default BENCH_distributed.json) so the perf trajectory can be
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import json, time, jax
from repro.core.distributed import distributed_pagerank
from repro.core.distributed_counts import distributed_pagerank_counts
from repro.core.distributed_directed import distributed_directed_pagerank
from repro.core.distributed_improved import distributed_improved_pagerank
from repro.graphs import directed_web, erdos_renyi

def phases(r):
    return dict(p1=r.phase1_rounds, report=r.report_rounds,
                p2=r.phase2_rounds, p3=r.phase3_rounds, tail=r.tail_rounds)

def coupons(r):
    return dict(created=r.coupons_created, used=r.coupons_used,
                exhausted=r.exhausted_walks)

g = erdos_renyi(200, 6.0, seed=3)
out = []
for K in (100, 400):
    t0 = time.time()
    rw = distributed_pagerank(g, 0.2, K, jax.random.PRNGKey(0))
    tw = time.time() - t0
    t0 = time.time()
    rc = distributed_pagerank_counts(g, 0.2, K, jax.random.PRNGKey(1))
    tc = time.time() - t0
    t0 = time.time()
    ri = distributed_improved_pagerank(g, 0.2, K, jax.random.PRNGKey(2))
    ti = time.time() - t0
    out.append(dict(K=K, shards=rw.shards,
                    walk_a2a=rw.a2a_bytes_total, walk_rounds=rw.rounds,
                    walk_us=tw * 1e6,
                    count_a2a=rc.a2a_bytes_total, count_rounds=rc.rounds,
                    count_us=tc * 1e6,
                    imp_a2a=ri.a2a_bytes_total, imp_rounds=ri.rounds,
                    imp_us=ti * 1e6,
                    imp_phases=phases(ri), imp_wire=ri.a2a_bytes_by_phase,
                    imp_coupons=coupons(ri)))

# Section 5 on a directed power-law web, vs Algorithm 1 on the same graph
# (the walk engine gets the worst-case W buffer: directed hubs overflow
# the 2*W/P CONGEST sizing)
gd = directed_web(200, 6.0, seed=3)
K = 50
t0 = time.time()
rdw = distributed_pagerank(gd, 0.2, K, jax.random.PRNGKey(3),
                           cap=gd.n * K + 8 * 64)
tdw = time.time() - t0
t0 = time.time()
rd = distributed_directed_pagerank(gd, 0.2, K, jax.random.PRNGKey(4))
td = time.time() - t0
out.append(dict(K=K, shards=rd.shards, directed=True,
                walk_a2a=rdw.a2a_bytes_total, walk_rounds=rdw.rounds,
                walk_us=tdw * 1e6,
                dir_a2a=rd.a2a_bytes_total, dir_rounds=rd.rounds,
                dir_us=td * 1e6,
                dir_phases=phases(rd), dir_wire=rd.a2a_bytes_by_phase,
                dir_coupons=coupons(rd),
                dir_budget=rd.uniform_budget, dir_dropped=rd.dropped))
print(json.dumps(out))
"""


def run(shard_counts=(2, 8)):
    rows = []
    for p in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = SRC
        res = subprocess.run([sys.executable, "-c", _CODE], env=env,
                             capture_output=True, text=True, timeout=3600)
        if res.returncode != 0:
            rows.append(dict(shards=p, error=res.stderr[-200:]))
            continue
        rows.extend(json.loads(res.stdout.strip().splitlines()[-1]))
    return rows


def _phase_str(ph):
    return "/".join(f"{n}={ph[n]}" for n in
                    ("p1", "report", "p2", "p3", "tail"))


def _wire_str(wire):
    return ";".join(f"{n}_bytes={v}" for n, v in sorted(wire.items()))


def report(rows):
    print("name,us_per_call,derived")
    for r in rows:
        if "error" in r:
            print(f"dist_shards{r['shards']},0,ERROR={r['error'][:80]}")
            continue
        p, k = r["shards"], r["K"]
        if r.get("directed"):
            cp = r["dir_coupons"]
            print(f"dist_dirwalk_P{p}_K{k},{r['walk_us']:.0f},"
                  f"rounds={r['walk_rounds']};a2a_bytes={r['walk_a2a']}")
            print(f"dist_directed_P{p}_K{k},{r['dir_us']:.0f},"
                  f"rounds={r['dir_rounds']};"
                  f"phases={_phase_str(r['dir_phases'])};"
                  f"{_wire_str(r['dir_wire'])};"
                  f"coupons_used={cp['used']}/{cp['created']};"
                  f"exhausted={cp['exhausted']};budget={r['dir_budget']};"
                  f"dropped={r['dir_dropped']};round_speedup="
                  f"{r['walk_rounds'] / max(r['dir_rounds'], 1):.2f}x")
            continue
        print(f"dist_walk_P{p}_K{k},{r['walk_us']:.0f},"
              f"rounds={r['walk_rounds']};a2a_bytes={r['walk_a2a']}")
        print(f"dist_count_P{p}_K{k},{r['count_us']:.0f},"
              f"rounds={r['count_rounds']};a2a_bytes={r['count_a2a']};"
              f"reduction={r['walk_a2a']/max(r['count_a2a'],1):.1f}x")
        cp = r["imp_coupons"]
        print(f"dist_improved_P{p}_K{k},{r['imp_us']:.0f},"
              f"rounds={r['imp_rounds']};"
              f"phases={_phase_str(r['imp_phases'])};"
              f"{_wire_str(r['imp_wire'])};"
              f"coupons_used={cp['used']}/{cp['created']};"
              f"exhausted={cp['exhausted']};"
              f"round_speedup={r['walk_rounds']/max(r['imp_rounds'],1):.2f}x")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_distributed.json",
                    default=None, metavar="PATH",
                    help="also write the raw rows (rounds, wire volume, "
                         "wall time per engine) to a JSON artifact")
    ap.add_argument("--shards", type=int, nargs="+", default=[2, 8])
    args = ap.parse_args(argv)
    rows = run(tuple(args.shards))
    report(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(schema=1, bench="distributed_engines",
                           shard_counts=args.shards, rows=rows), f, indent=2)
        print(f"[bench] wrote {args.json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
