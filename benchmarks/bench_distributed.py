"""Distributed engine scaling: Algorithm 1 (walk-routing and
count-aggregated wire) vs Algorithm 2 (sharded IMPROVED-PAGERANK).

Reproduces the §Perf hillclimb measurements: all_to_all payload and round
counts to full termination for all three engines at 2/8 shards and two
walk counts (subprocess per shard count — device count is process-global).
Emitted columns per engine: wall time, total rounds, phase-round breakdown
(Algorithm 2 only: p1/report/p2/p3/tail), and wire volume (total
all_to_all payload bytes, by phase for Algorithm 2).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import json, time, jax
from repro.core.distributed import distributed_pagerank
from repro.core.distributed_counts import distributed_pagerank_counts
from repro.core.distributed_improved import distributed_improved_pagerank
from repro.graphs import erdos_renyi
g = erdos_renyi(200, 6.0, seed=3)
out = []
for K in (100, 400):
    t0 = time.time()
    rw = distributed_pagerank(g, 0.2, K, jax.random.PRNGKey(0))
    tw = time.time() - t0
    t0 = time.time()
    rc = distributed_pagerank_counts(g, 0.2, K, jax.random.PRNGKey(1))
    tc = time.time() - t0
    t0 = time.time()
    ri = distributed_improved_pagerank(g, 0.2, K, jax.random.PRNGKey(2))
    ti = time.time() - t0
    out.append(dict(K=K, shards=rw.shards,
                    walk_a2a=rw.a2a_bytes_total, walk_rounds=rw.rounds,
                    walk_us=tw * 1e6,
                    count_a2a=rc.a2a_bytes_total, count_rounds=rc.rounds,
                    count_us=tc * 1e6,
                    imp_a2a=ri.a2a_bytes_total, imp_rounds=ri.rounds,
                    imp_us=ti * 1e6,
                    imp_phases=dict(p1=ri.phase1_rounds,
                                    report=ri.report_rounds,
                                    p2=ri.phase2_rounds,
                                    p3=ri.phase3_rounds,
                                    tail=ri.tail_rounds),
                    imp_wire=ri.a2a_bytes_by_phase,
                    imp_coupons=dict(created=ri.coupons_created,
                                     used=ri.coupons_used,
                                     exhausted=ri.exhausted_walks)))
print(json.dumps(out))
"""


def run(shard_counts=(2, 8)):
    rows = []
    for p in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = SRC
        res = subprocess.run([sys.executable, "-c", _CODE], env=env,
                             capture_output=True, text=True, timeout=1800)
        if res.returncode != 0:
            rows.append(dict(shards=p, error=res.stderr[-200:]))
            continue
        rows.extend(json.loads(res.stdout.strip().splitlines()[-1]))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        if "error" in r:
            print(f"dist_shards{r['shards']},0,ERROR={r['error'][:80]}")
            continue
        p, k = r["shards"], r["K"]
        print(f"dist_walk_P{p}_K{k},{r['walk_us']:.0f},"
              f"rounds={r['walk_rounds']};a2a_bytes={r['walk_a2a']}")
        print(f"dist_count_P{p}_K{k},{r['count_us']:.0f},"
              f"rounds={r['count_rounds']};a2a_bytes={r['count_a2a']};"
              f"reduction={r['walk_a2a']/max(r['count_a2a'],1):.1f}x")
        ph = r["imp_phases"]
        phase_s = "/".join(f"{n}={ph[n]}" for n in
                           ("p1", "report", "p2", "p3", "tail"))
        wire_s = ";".join(f"{n}_bytes={v}"
                          for n, v in sorted(r["imp_wire"].items()))
        cp = r["imp_coupons"]
        print(f"dist_improved_P{p}_K{k},{r['imp_us']:.0f},"
              f"rounds={r['imp_rounds']};phases={phase_s};{wire_s};"
              f"coupons_used={cp['used']}/{cp['created']};"
              f"exhausted={cp['exhausted']};"
              f"round_speedup={r['walk_rounds']/max(r['imp_rounds'],1):.2f}x")
    return rows


if __name__ == "__main__":
    main()
