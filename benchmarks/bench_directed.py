"""Theorem 3: directed/LOCAL variant rounds + message sizes.

LOCAL removes the bandwidth cap, so the deliverable is logical rounds
(lambda + stitches + lambda) and the per-node message volume (polynomial,
as the paper states — contrasted with the CONGEST variants).
"""
from __future__ import annotations

import time

import jax

from repro.core import directed_local_pagerank, l1_error, normalized, power_iteration
from repro.graphs import directed_web


def run(sizes=(64, 128, 256), eps=0.2, K=40):
    rows = []
    for n in sizes:
        g = directed_web(n, 6.0, seed=1)
        pi_ref, _, _ = power_iteration(g, eps)
        t0 = time.time()
        r = directed_local_pagerank(g, eps, walks_per_node=K,
                                    key=jax.random.PRNGKey(n))
        rows.append(dict(
            n=n,
            lam=r.lam,
            logical=r.phase1_rounds + r.phase2_rounds + r.phase3_rounds,
            stitches=r.stitch_iterations,
            coupons=r.coupons_created,
            l1=l1_error(normalized(r.pi), pi_ref),
            us=(time.time() - t0) * 1e6,
        ))
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"directed_local_n{r['n']},{r['us']:.0f},"
              f"logical_rounds={r['logical']};lam={r['lam']};"
              f"coupons={r['coupons']};l1={r['l1']:.4f}")
    return rows


if __name__ == "__main__":
    main()
