"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_seconds(s):
    return f"{s*1e3:.1f}ms" if s < 10 else f"{s:.1f}s"


def table(cells, mesh="pod16x16"):
    rows = []
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            rows.append((c["arch"], c["shape"], "SKIP", "", "", "", "", "",
                         c.get("reason", "")[:40]))
            continue
        if c["status"] != "ok":
            rows.append((c["arch"], c["shape"], "ERR", "", "", "", "", "",
                         c.get("reason", "")[:40]))
            continue
        r = c["roofline"]
        rows.append((
            c["arch"], c["shape"],
            fmt_seconds(r["t_compute"]), fmt_seconds(r["t_memory"]),
            fmt_seconds(r["t_collective"]), r["bottleneck"],
            f"{r['useful_flops_fraction']:.2f}",
            f"{r['mfu']:.3f}",
            "",
        ))
    return rows


def main():
    cells = load_cells()
    ok = sum(1 for c in cells if c["status"] == "ok")
    err = sum(1 for c in cells if c["status"] == "error")
    skip = sum(1 for c in cells if c["status"] == "skipped")
    print(f"# cells: {len(cells)}  ok={ok} err={err} skipped={skip}")
    print("name,us_per_call,derived")
    for c in cells:
        if c["status"] != "ok":
            print(f"roofline_{c['cell']},0,status={c['status']}")
            continue
        r = c["roofline"]
        print(f"roofline_{c['cell']},0,"
              f"bottleneck={r['bottleneck']};step={r['step_time']:.4f}s;"
              f"mfu={r['mfu']:.4f};useful={r['useful_flops_fraction']:.3f}")


if __name__ == "__main__":
    main()
