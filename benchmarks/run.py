"""Benchmark harness entry: one module per paper claim/table.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV per benchmark:
  bench_rounds      — Theorem 1 & 2 round complexity scaling
  bench_accuracy    — Monte-Carlo accuracy vs K (Avrachenkov claim)
  bench_congestion  — Lemma 1/3 per-edge message bits
  bench_directed    — Theorem 3 directed/LOCAL variant
  bench_engines     — engine throughput (counts vs walk-array vs baseline)
  bench_distributed — multi-shard wire volume: walk-routing vs count lanes
  bench_serve       — PPR query serving: Poisson traffic qps + latency
  bench_kernels     — Pallas kernel micro-benches + TPU roofline estimates
  roofline_report   — dry-run roofline aggregation (all cells)
"""
import importlib

MODULES = [
    "benchmarks.bench_rounds",
    "benchmarks.bench_accuracy",
    "benchmarks.bench_congestion",
    "benchmarks.bench_directed",
    "benchmarks.bench_engines",
    "benchmarks.bench_distributed",
    "benchmarks.bench_serve",
    "benchmarks.bench_kernels",
    "benchmarks.roofline_report",
]


def main() -> None:
    for name in MODULES:
        print(f"\n=== {name} ===", flush=True)
        try:
            importlib.import_module(name).main()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name},0,ERROR={type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
